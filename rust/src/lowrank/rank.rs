//! Adaptive rank selection — paper §3.2.
//!
//! Four strategies, matching the paper's list verbatim:
//!
//! 1. **Fixed fraction**: `r = α · min(m, n)`, `α ∈ [0.01, 0.1]`.
//! 2. **Energy-based**: smallest `r` with `Σ_{j≤r} σ_j² ≥ τ · ‖A‖_F²`.
//! 3. **Error-constrained**: smallest `r` whose Eckart–Young tail error is
//!    below a relative threshold.
//! 4. **Hardware-aware**: the largest rank whose factor working set fits a
//!    memory budget (and respects an alignment granule so the MXU/TensorCore
//!    tiles stay full).

use crate::gpu_sim::profile::DeviceProfile;

/// Rank-selection strategy (paper §3.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RankStrategy {
    /// Explicit rank.
    Fixed(usize),
    /// `r = α · min(m, n)`.
    FixedFraction(f32),
    /// Retain the smallest rank capturing this fraction of spectral energy.
    EnergyFraction(f32),
    /// Smallest rank with relative Frobenius tail error ≤ this bound.
    ErrorBound(f32),
    /// Largest hardware-friendly rank whose factors fit the device budget.
    HardwareAware {
        /// Fraction of device memory the factors may use (e.g. 0.15).
        memory_fraction: f32,
        /// Round the rank down to a multiple of this (tile granule).
        granule: usize,
    },
}

impl RankStrategy {
    /// Human name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            RankStrategy::Fixed(_) => "fixed",
            RankStrategy::FixedFraction(_) => "fixed_fraction",
            RankStrategy::EnergyFraction(_) => "energy",
            RankStrategy::ErrorBound(_) => "error_bound",
            RankStrategy::HardwareAware { .. } => "hardware_aware",
        }
    }
}

/// Select a rank for an `m×n` matrix with (estimated or exact) singular
/// values `sv` (non-increasing). `device` is consulted only by the
/// hardware-aware strategy. Always returns `1 ≤ r ≤ min(m, n, sv.len())`
/// (or `min(m,n)` when `sv` is empty and the strategy is spectrum-free).
pub fn select_rank(
    strategy: &RankStrategy,
    m: usize,
    n: usize,
    sv: &[f32],
    device: &DeviceProfile,
) -> usize {
    let kmax = m.min(n).max(1);
    let clamp = |r: usize| r.clamp(1, kmax);
    match *strategy {
        RankStrategy::Fixed(r) => clamp(r),
        RankStrategy::FixedFraction(alpha) => clamp((alpha * kmax as f32).round() as usize),
        RankStrategy::EnergyFraction(tau) => {
            let sv = &sv[..sv.len().min(kmax)];
            if sv.is_empty() {
                return 1;
            }
            let total: f64 = sv.iter().map(|&s| (s as f64) * (s as f64)).sum();
            if total <= 0.0 {
                return 1;
            }
            let mut acc = 0.0f64;
            for (j, &s) in sv.iter().enumerate() {
                acc += (s as f64) * (s as f64);
                if acc / total >= tau as f64 {
                    return clamp(j + 1);
                }
            }
            clamp(sv.len())
        }
        RankStrategy::ErrorBound(eps) => {
            // Tail error after r terms: sqrt(Σ_{j>r} σ²) / ‖A‖_F ≤ eps.
            let sv = &sv[..sv.len().min(kmax)];
            if sv.is_empty() {
                return kmax; // no spectrum info: be safe
            }
            let total: f64 = sv.iter().map(|&s| (s as f64) * (s as f64)).sum();
            if total <= 0.0 {
                return 1;
            }
            let mut tail = total;
            for (j, &s) in sv.iter().enumerate() {
                tail -= (s as f64) * (s as f64);
                if (tail.max(0.0) / total).sqrt() <= eps as f64 {
                    return clamp(j + 1);
                }
            }
            clamp(sv.len())
        }
        RankStrategy::HardwareAware {
            memory_fraction,
            granule,
        } => {
            // Factors for BOTH operands plus the rank-sized core:
            // bytes ≈ (m + n) r + r² per matrix pair at 1 B/elt (FP8).
            let budget = (device.memory_bytes as f64 * memory_fraction as f64).max(1.0);
            // Solve (m + n) r + r² ≤ budget for r (quadratic formula).
            let p = (m + n) as f64;
            let r = ((-p + (p * p + 4.0 * budget).sqrt()) / 2.0).floor() as usize;
            let g = granule.max(1);
            let r = (r / g) * g;
            clamp(r.max(g.min(kmax)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_sim::profile::DeviceProfile;

    fn dev() -> DeviceProfile {
        DeviceProfile::rtx4090()
    }

    #[test]
    fn fixed_clamped() {
        assert_eq!(select_rank(&RankStrategy::Fixed(5), 10, 8, &[], &dev()), 5);
        assert_eq!(select_rank(&RankStrategy::Fixed(0), 10, 8, &[], &dev()), 1);
        assert_eq!(select_rank(&RankStrategy::Fixed(99), 10, 8, &[], &dev()), 8);
    }

    #[test]
    fn fixed_fraction_paper_range() {
        // Paper: α ∈ [0.01, 0.1]; at N=20480, α=0.025 → r=512.
        let r = select_rank(&RankStrategy::FixedFraction(0.025), 20480, 20480, &[], &dev());
        assert_eq!(r, 512);
    }

    #[test]
    fn energy_fraction_on_known_spectrum() {
        // sv² = [100, 25, 1, 0.01] → energy fractions 0.7936.., 0.992.., ...
        let sv = [10.0, 5.0, 1.0, 0.1];
        assert_eq!(
            select_rank(&RankStrategy::EnergyFraction(0.79), 20, 20, &sv, &dev()),
            1
        );
        assert_eq!(
            select_rank(&RankStrategy::EnergyFraction(0.99), 20, 20, &sv, &dev()),
            2
        );
        assert_eq!(
            select_rank(&RankStrategy::EnergyFraction(0.9999), 20, 20, &sv, &dev()),
            3
        );
    }

    #[test]
    fn energy_fraction_degenerate() {
        assert_eq!(select_rank(&RankStrategy::EnergyFraction(0.99), 5, 5, &[], &dev()), 1);
        assert_eq!(
            select_rank(&RankStrategy::EnergyFraction(0.99), 5, 5, &[0.0, 0.0], &dev()),
            1
        );
    }

    #[test]
    fn error_bound_monotone_in_eps() {
        let sv: Vec<f32> = (0..32).map(|i| (0.8f32).powi(i)).collect();
        let tight = select_rank(&RankStrategy::ErrorBound(0.001), 64, 64, &sv, &dev());
        let loose = select_rank(&RankStrategy::ErrorBound(0.1), 64, 64, &sv, &dev());
        assert!(tight > loose, "tight {tight} loose {loose}");
    }

    #[test]
    fn error_bound_without_spectrum_is_safe() {
        assert_eq!(select_rank(&RankStrategy::ErrorBound(0.01), 6, 9, &[], &dev()), 6);
    }

    #[test]
    fn hardware_aware_fits_budget_and_granule() {
        let d = dev();
        let strat = RankStrategy::HardwareAware {
            memory_fraction: 0.15,
            granule: 16,
        };
        let (m, n) = (20480usize, 20480usize);
        let r = select_rank(&strat, m, n, &[], &d);
        assert_eq!(r % 16, 0);
        let bytes = ((m + n) * r + r * r) as f64;
        assert!(bytes <= d.memory_bytes as f64 * 0.15);
        // And it should be generous at this scale (paper uses r=512).
        assert!(r >= 512, "r = {r}");
    }

    #[test]
    fn hardware_aware_small_matrix() {
        let strat = RankStrategy::HardwareAware {
            memory_fraction: 0.15,
            granule: 16,
        };
        let r = select_rank(&strat, 8, 8, &[], &dev());
        assert!((1..=8).contains(&r));
    }
}
