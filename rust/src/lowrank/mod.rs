//! The paper's core contribution: low-rank GEMM.
//!
//! `C = A·B ≈ U_A (Σ_A V_Aᵀ U_B) Σ_B V_Bᵀ` (paper Eq. 1), with
//!
//! - [`factor`]: the factorized representation ([`LowRankFactor`]) and its
//!   memory accounting (the paper's 75%-savings claim),
//! - [`gemm`]: the factor-chain multiplication, ordered so every
//!   intermediate is rank-sized (`O((m+k+n)r²)` — paper §3.1),
//! - [`rank`]: the four adaptive rank-selection strategies (§3.2),
//! - [`errors`]: Eckart–Young bounds and measured-error helpers (§5.4),
//! - [`cache`]: the offline-decomposition factor cache (§6.5's
//!   "decomposition ideally computed in advance").

pub mod cache;
pub mod errors;
pub mod factor;
pub mod gemm;
pub mod rank;

pub use cache::FactorCache;
pub use errors::{eckart_young_error, eckart_young_rel_error, energy_capture, measured_rel_error, predicted_rel_error};
pub use factor::{DecompMethod, LowRankConfig, LowRankFactor};
pub use gemm::{factorize, lowrank_matmul, lowrank_matmul_dense_lhs, lowrank_matmul_dense_rhs};
pub use rank::{select_rank, RankStrategy};
pub use cache::{CacheStats, MatrixId};
