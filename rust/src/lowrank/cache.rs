//! Offline-decomposition factor cache — paper §6.5.
//!
//! "For best performance, the low-rank factorization of matrices is
//! ideally computed in advance." In the serving system this is an LRU
//! cache keyed by a caller-supplied matrix identity (weights are stable
//! across requests; activations are not and take the dense path). The
//! cache is byte-budgeted, not entry-budgeted, because factor size varies
//! with rank: evictions free the least-recently-used factors until the new
//! entry fits.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::lowrank::factor::LowRankFactor;

/// Stable identity for a cached matrix (e.g. a weight tensor id).
pub type MatrixId = u64;

/// Hit/miss counters (snapshot via [`FactorCache::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups that found a live factor.
    pub hits: u64,
    /// Lookups that missed.
    pub misses: u64,
    /// Entries evicted to make room.
    pub evictions: u64,
    /// Current resident bytes.
    pub resident_bytes: u64,
    /// Current entry count.
    pub entries: u64,
}

struct Entry {
    factor: LowRankFactor,
    bytes: usize,
    last_used: u64,
}

struct Inner {
    map: HashMap<MatrixId, Entry>,
    clock: u64,
    resident: usize,
    stats: CacheStats,
}

/// Thread-safe, byte-budgeted LRU cache of low-rank factors.
pub struct FactorCache {
    budget_bytes: usize,
    inner: Mutex<Inner>,
}

impl FactorCache {
    /// Create a cache with the given byte budget.
    pub fn new(budget_bytes: usize) -> Self {
        FactorCache {
            budget_bytes,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                clock: 0,
                resident: 0,
                stats: CacheStats::default(),
            }),
        }
    }

    /// Look up a factor; clones on hit (factors are cheap to clone relative
    /// to recomputation — the payload Vec is the bulk and must cross the
    /// worker boundary anyway).
    pub fn get(&self, id: MatrixId) -> Option<LowRankFactor> {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        match g.map.get_mut(&id) {
            Some(e) => {
                e.last_used = clock;
                let f = e.factor.clone();
                g.stats.hits += 1;
                Some(f)
            }
            None => {
                g.stats.misses += 1;
                None
            }
        }
    }

    /// Presence probe that neither clones nor perturbs LRU order or
    /// hit/miss stats (used by the router, which only *plans*).
    pub fn contains(&self, id: MatrixId) -> bool {
        self.inner.lock().unwrap().map.contains_key(&id)
    }

    /// Insert (or replace) a factor, evicting LRU entries as needed.
    /// Factors larger than the whole budget are rejected (returns false).
    pub fn put(&self, id: MatrixId, factor: LowRankFactor) -> bool {
        let bytes = factor.storage_bytes();
        if bytes > self.budget_bytes {
            return false;
        }
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        if let Some(old) = g.map.remove(&id) {
            g.resident -= old.bytes;
        }
        while g.resident + bytes > self.budget_bytes {
            // Evict the least recently used entry.
            let victim = g
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&k, _)| k);
            match victim {
                Some(k) => {
                    let e = g.map.remove(&k).unwrap();
                    g.resident -= e.bytes;
                    g.stats.evictions += 1;
                }
                None => break,
            }
        }
        g.resident += bytes;
        g.map.insert(
            id,
            Entry {
                factor,
                bytes,
                last_used: clock,
            },
        );
        g.stats.resident_bytes = g.resident as u64;
        g.stats.entries = g.map.len() as u64;
        true
    }

    /// Fetch-or-compute: single-flight is unnecessary at our concurrency
    /// level (workers share one CPU); duplicate computes are benign.
    pub fn get_or_insert_with(
        &self,
        id: MatrixId,
        make: impl FnOnce() -> crate::error::Result<LowRankFactor>,
    ) -> crate::error::Result<LowRankFactor> {
        if let Some(f) = self.get(id) {
            return Ok(f);
        }
        let f = make()?;
        self.put(id, f.clone());
        Ok(f)
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        let mut g = self.inner.lock().unwrap();
        g.stats.resident_bytes = g.resident as u64;
        g.stats.entries = g.map.len() as u64;
        g.stats
    }

    /// Drop everything (tests / reconfiguration).
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.map.clear();
        g.resident = 0;
        g.stats.resident_bytes = 0;
        g.stats.entries = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::StorageFormat;
    use crate::linalg::matrix::Matrix;
    use crate::linalg::rng::Pcg64;
    use crate::lowrank::factor::{DecompMethod, LowRankConfig};
    use crate::lowrank::gemm::factorize;
    use crate::lowrank::rank::RankStrategy;

    fn make_factor(seed: u64, n: usize, r: usize) -> LowRankFactor {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::low_rank(n, n, r, &mut rng);
        factorize(
            &a,
            &LowRankConfig {
                rank: RankStrategy::Fixed(r),
                method: DecompMethod::RandomizedSvd,
                storage: StorageFormat::F32,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn hit_after_put() {
        let cache = FactorCache::new(1 << 20);
        let f = make_factor(1, 16, 2);
        assert!(cache.put(7, f));
        assert!(cache.get(7).is_some());
        let s = cache.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn miss_counts() {
        let cache = FactorCache::new(1 << 20);
        assert!(cache.get(42).is_none());
        assert_eq!(cache.stats().misses, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let f = make_factor(2, 16, 2);
        let bytes = f.storage_bytes();
        // Budget for exactly 2 entries.
        let cache = FactorCache::new(2 * bytes + bytes / 2);
        cache.put(1, f.clone());
        cache.put(2, f.clone());
        cache.get(1); // make 2 the LRU
        cache.put(3, f.clone());
        assert!(cache.get(1).is_some(), "recently used survives");
        assert!(cache.get(2).is_none(), "LRU evicted");
        assert!(cache.get(3).is_some());
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn oversized_rejected() {
        let f = make_factor(3, 64, 8);
        let cache = FactorCache::new(f.storage_bytes() - 1);
        assert!(!cache.put(1, f));
        assert_eq!(cache.stats().entries, 0);
    }

    #[test]
    fn replace_same_id_updates_bytes() {
        let small = make_factor(4, 16, 2);
        let big = make_factor(5, 32, 4);
        let cache = FactorCache::new(1 << 20);
        cache.put(1, small.clone());
        let before = cache.stats().resident_bytes;
        cache.put(1, big.clone());
        let after = cache.stats().resident_bytes;
        assert_eq!(cache.stats().entries, 1);
        assert!(after > before);
    }

    #[test]
    fn get_or_insert_computes_once_per_miss() {
        let cache = FactorCache::new(1 << 20);
        let mut computed = 0;
        for _ in 0..3 {
            cache
                .get_or_insert_with(9, || {
                    computed += 1;
                    Ok(make_factor(6, 16, 2))
                })
                .unwrap();
        }
        assert_eq!(computed, 1);
        assert_eq!(cache.stats().hits, 2);
    }

    #[test]
    fn clear_resets() {
        let cache = FactorCache::new(1 << 20);
        cache.put(1, make_factor(7, 16, 2));
        cache.clear();
        assert_eq!(cache.stats().entries, 0);
        assert_eq!(cache.stats().resident_bytes, 0);
        assert!(cache.get(1).is_none());
    }

    #[test]
    fn concurrent_access_is_safe() {
        use std::sync::Arc;
        let cache = Arc::new(FactorCache::new(1 << 22));
        let f = make_factor(8, 24, 3);
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = Arc::clone(&cache);
            let f = f.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50u64 {
                    let id = (t * 50 + i) % 13;
                    if i % 3 == 0 {
                        c.put(id, f.clone());
                    } else {
                        c.get(id);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = cache.stats();
        assert!(s.hits + s.misses > 0);
    }
}
