//! The low-rank GEMM itself — paper Eq. (1) and §3.1.
//!
//! `C ≈ U_A (Σ_A V_Aᵀ U_B) Σ_B V_Bᵀ` evaluated strictly inside-out so no
//! intermediate is ever larger than `max(m, n) × r`:
//!
//! ```text
//!   T1 = V_Aᵀ U_B          (r_a × r_b)     O(k r_a r_b)
//!   T2 = Σ_A T1 Σ_B        (r_a × r_b)     O(r_a r_b)
//!   T3 = T2 V_Bᵀ           (r_a × n)       O(r_a r_b n)
//!   C  = U_A T3            (m × n)         O(m r_a n)
//! ```
//!
//! The final product is the dominant term; the paper's `O((m+k+n) r²)`
//! analysis corresponds to the factor-domain work (T1–T3), with the dense
//! reconstruction charged only when a dense C is actually required —
//! the serving path keeps results factored whenever the consumer accepts
//! factored output.

use crate::error::Result;
use crate::linalg::matrix::Matrix;
use crate::linalg::rsvd::rsvd;
use crate::linalg::svd::truncated_svd;
use crate::lowrank::factor::{DecompMethod, LowRankConfig, LowRankFactor};
use crate::lowrank::rank::{select_rank, RankStrategy};

/// Decompose a dense matrix according to `cfg`, returning the quantized
/// factor. This is the **offline** step of the paper's pipeline (§6.5):
/// in serving, its output lives in the [`crate::lowrank::FactorCache`].
pub fn factorize(a: &Matrix, cfg: &LowRankConfig) -> Result<LowRankFactor> {
    let (m, n) = a.shape();
    let kmax = m.min(n);

    // Strategies that need the spectrum get it from a cheap probe
    // decomposition; spectrum-free strategies skip it.
    let rank = match cfg.rank {
        RankStrategy::Fixed(_) | RankStrategy::FixedFraction(_) | RankStrategy::HardwareAware { .. } => {
            select_rank(
                &cfg.rank,
                m,
                n,
                &[],
                &crate::gpu_sim::profile::DeviceProfile::rtx4090(),
            )
        }
        RankStrategy::EnergyFraction(_) | RankStrategy::ErrorBound(_) => {
            // Probe with a generous sketch (¼ of the spectrum, ≥ 8) and
            // select from the estimated singular values.
            let probe_rank = (kmax / 4).clamp(1, kmax.min(64).max(1));
            let probe = rsvd(a, probe_rank, &cfg.rsvd)?;
            select_rank(
                &cfg.rank,
                m,
                n,
                &probe.s,
                &crate::gpu_sim::profile::DeviceProfile::rtx4090(),
            )
        }
    };
    let rank = rank.clamp(1, kmax);

    let svd = match cfg.method {
        DecompMethod::ExactSvd => truncated_svd(a, rank)?,
        DecompMethod::RandomizedSvd => rsvd(a, rank, &cfg.rsvd)?,
        DecompMethod::Lanczos => crate::linalg::lanczos::lanczos_svd(a, rank, 6, cfg.rsvd.seed)?,
    };

    Ok(LowRankFactor::from_svd(
        &svd.u,
        svd.s,
        &svd.vt,
        cfg.storage,
        a.shape(),
        cfg.method,
    ))
}

/// Factor-chain GEMM: multiply two factored matrices, producing dense C.
///
/// Panics only on internal shape corruption (factors are validated on
/// construction); mismatched logical shapes (`A.cols != B.rows`) are the
/// caller's contract, checked with a debug assert to keep the hot path
/// branch-free in release.
pub fn lowrank_matmul(fa: &LowRankFactor, fb: &LowRankFactor) -> Matrix {
    debug_assert_eq!(
        fa.orig_shape.1, fb.orig_shape.0,
        "low-rank GEMM inner dimension"
    );
    let ua = fa.u_dense(); // m × ra
    let vat = fa.vt_dense(); // ra × k
    let ub = fb.u_dense(); // k × rb
    let vbt = fb.vt_dense(); // rb × n

    // T1 = V_Aᵀ · U_B  (ra × rb): the only pass over the shared dim k.
    let t1 = vat.matmul(&ub);

    // T2 = Σ_A · T1 · Σ_B, applied as row/col scalings (no materialized diag).
    let mut t2 = t1;
    t2.scale_rows_in_place(&fa.s);
    t2.scale_cols_in_place(&fb.s);

    // Contract toward the cheaper side first: if m ≤ n it is cheaper to do
    // (U_A · T2) · V_Bᵀ, otherwise U_A · (T2 · V_Bᵀ).
    let (m, _) = fa.orig_shape;
    let (_, n) = fb.orig_shape;
    if m <= n {
        ua.matmul(&t2).matmul(&vbt)
    } else {
        ua.matmul(&t2.matmul(&vbt))
    }
}

/// Factor × dense GEMM (`A` factored, `B` dense): the common serving case
/// where weights are offline-factorized but activations arrive dense.
/// `C = U_A Σ_A (V_Aᵀ B)` — cost `O(k r n + m r n)`, never `O(m k n)`.
pub fn lowrank_matmul_dense_rhs(fa: &LowRankFactor, b: &Matrix) -> Matrix {
    debug_assert_eq!(fa.orig_shape.1, b.rows(), "low-rank×dense inner dimension");
    let vat = fa.vt_dense(); // r × k
    let mut t = vat.matmul(b); // r × n
    t.scale_rows_in_place(&fa.s);
    fa.u_dense().matmul(&t)
}

/// Dense × factor GEMM (`A` dense, `B` factored): the mirrored serving
/// case (activation × factorized weight — `x · W`).
/// `C = ((A U_B) Σ_B) V_Bᵀ` — cost `O(m k r + m r n)`.
pub fn lowrank_matmul_dense_lhs(a: &Matrix, fb: &LowRankFactor) -> Matrix {
    debug_assert_eq!(a.cols(), fb.orig_shape.0, "dense×low-rank inner dimension");
    let ub = fb.u_dense(); // k × r
    let mut t = a.matmul(&ub); // m × r
    t.scale_cols_in_place(&fb.s);
    t.matmul(&fb.vt_dense())
}

/// FLOP count of the factor-chain GEMM (dense reconstruction included),
/// used by the cost model and the benchmark reporters.
pub fn lowrank_flops(m: usize, k: usize, n: usize, ra: usize, rb: usize) -> f64 {
    let t1 = 2.0 * ra as f64 * k as f64 * rb as f64;
    let t2 = ra as f64 * rb as f64 * 2.0;
    let (t3, c) = if m <= n {
        (
            2.0 * m as f64 * ra as f64 * rb as f64,
            2.0 * m as f64 * rb as f64 * n as f64,
        )
    } else {
        (
            2.0 * ra as f64 * rb as f64 * n as f64,
            2.0 * m as f64 * ra as f64 * n as f64,
        )
    };
    t1 + t2 + t3 + c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::{Fp8Format, StorageFormat};
    use crate::linalg::rng::Pcg64;

    fn cfg(rank: usize) -> LowRankConfig {
        LowRankConfig {
            rank: RankStrategy::Fixed(rank),
            method: DecompMethod::RandomizedSvd,
            storage: StorageFormat::F32,
            ..Default::default()
        }
    }

    #[test]
    fn exact_on_truly_low_rank_inputs() {
        let mut rng = Pcg64::seeded(71);
        let a = Matrix::low_rank(40, 32, 4, &mut rng);
        let b = Matrix::low_rank(32, 36, 4, &mut rng);
        let fa = factorize(&a, &cfg(4)).unwrap();
        let fb = factorize(&b, &cfg(4)).unwrap();
        let c = lowrank_matmul(&fa, &fb);
        let exact = a.matmul(&b);
        assert!(c.rel_frobenius_distance(&exact) < 1e-3);
    }

    #[test]
    fn error_grows_as_rank_shrinks() {
        let mut rng = Pcg64::seeded(72);
        let sv: Vec<f32> = (0..24).map(|i| (0.7f32).powi(i)).collect();
        let a = Matrix::with_spectrum(48, 48, &sv, &mut rng);
        let b = Matrix::with_spectrum(48, 48, &sv, &mut rng);
        let exact = a.matmul(&b);
        let mut prev = 0.0f32;
        for r in [24, 12, 6, 3] {
            let fa = factorize(&a, &cfg(r)).unwrap();
            let fb = factorize(&b, &cfg(r)).unwrap();
            let err = lowrank_matmul(&fa, &fb).rel_frobenius_distance(&exact);
            // Shrinking the rank must not *reduce* the error (small slack
            // for quantization noise at the crossover).
            assert!(err + 1e-6 >= prev, "rank {r}: err {err} prev {prev}");
            prev = err;
        }
        assert!(prev > 1e-4, "rank-3 should show visible error");
    }

    #[test]
    fn dense_rhs_path_matches_factored_path() {
        let mut rng = Pcg64::seeded(73);
        let a = Matrix::low_rank(30, 26, 5, &mut rng);
        let b = Matrix::gaussian(26, 22, &mut rng);
        let fa = factorize(&a, &cfg(5)).unwrap();
        let c1 = lowrank_matmul_dense_rhs(&fa, &b);
        let exact = a.matmul(&b);
        assert!(c1.rel_frobenius_distance(&exact) < 1e-3);
    }

    #[test]
    fn dense_lhs_path_matches_exact() {
        let mut rng = Pcg64::seeded(78);
        let a = Matrix::gaussian(22, 26, &mut rng);
        let b = Matrix::low_rank(26, 30, 5, &mut rng);
        let fb = factorize(&b, &cfg(5)).unwrap();
        let c1 = lowrank_matmul_dense_lhs(&a, &fb);
        let exact = a.matmul(&b);
        assert!(c1.rel_frobenius_distance(&exact) < 1e-3);
    }

    #[test]
    fn lhs_and_rhs_mixed_paths_agree() {
        // x·W via dense_lhs must equal (Wᵀ·xᵀ)ᵀ via dense_rhs.
        let mut rng = Pcg64::seeded(79);
        let x = Matrix::gaussian(18, 24, &mut rng);
        let w = Matrix::low_rank(24, 20, 4, &mut rng);
        let fw = factorize(&w, &cfg(4)).unwrap();
        let c1 = lowrank_matmul_dense_lhs(&x, &fw);
        let wt = w.transpose();
        let fwt = factorize(&wt, &cfg(4)).unwrap();
        let c2 = lowrank_matmul_dense_rhs(&fwt, &x.transpose()).transpose();
        assert!(c1.rel_frobenius_distance(&c2) < 1e-3);
    }

    #[test]
    fn fp8_storage_end_to_end_error_in_paper_band() {
        // Paper §5.4: low-rank + FP8 lands at ~1-2% relative error.
        let mut rng = Pcg64::seeded(74);
        let a = Matrix::low_rank_noisy(64, 64, 8, 1e-3, &mut rng);
        let b = Matrix::low_rank_noisy(64, 64, 8, 1e-3, &mut rng);
        let c8 = LowRankConfig {
            rank: RankStrategy::Fixed(8),
            storage: StorageFormat::Fp8(Fp8Format::E4M3),
            ..Default::default()
        };
        let fa = factorize(&a, &c8).unwrap();
        let fb = factorize(&b, &c8).unwrap();
        let err = lowrank_matmul(&fa, &fb).rel_frobenius_distance(&a.matmul(&b));
        assert!(err < 0.06, "err {err}");
        assert!(err > 1e-4, "fp8 error should be visible, got {err}");
    }

    #[test]
    fn energy_strategy_adapts_to_spectrum() {
        let mut rng = Pcg64::seeded(75);
        // Fast decay → small rank; slow decay → larger rank.
        let fast: Vec<f32> = (0..32).map(|i| (0.3f32).powi(i)).collect();
        let slow: Vec<f32> = (0..32).map(|i| (0.95f32).powi(i)).collect();
        let a_fast = Matrix::with_spectrum(64, 64, &fast, &mut rng);
        let a_slow = Matrix::with_spectrum(64, 64, &slow, &mut rng);
        let c = LowRankConfig {
            rank: RankStrategy::EnergyFraction(0.99),
            ..Default::default()
        };
        let rf = factorize(&a_fast, &c).unwrap().rank();
        let rs = factorize(&a_slow, &c).unwrap().rank();
        assert!(rf < rs, "fast {rf} vs slow {rs}");
    }

    #[test]
    fn all_three_methods_agree_on_easy_input() {
        let mut rng = Pcg64::seeded(76);
        let a = Matrix::low_rank(36, 30, 4, &mut rng);
        for method in [DecompMethod::ExactSvd, DecompMethod::RandomizedSvd, DecompMethod::Lanczos] {
            let c = LowRankConfig {
                rank: RankStrategy::Fixed(4),
                method,
                ..Default::default()
            };
            let f = factorize(&a, &c).unwrap();
            let err = f.measured_error(&a);
            assert!(err < 5e-3, "{:?}: err {err}", method);
        }
    }

    #[test]
    fn flops_less_than_dense_for_small_rank() {
        let dense = crate::linalg::gemm::gemm_flops(2048, 2048, 2048);
        let lr = lowrank_flops(2048, 2048, 2048, 64, 64);
        assert!(lr < dense / 10.0, "lr {lr} dense {dense}");
    }

    #[test]
    fn contraction_order_picks_cheaper_side() {
        // Just exercise both branches for correctness.
        let mut rng = Pcg64::seeded(77);
        let a = Matrix::low_rank(50, 20, 3, &mut rng); // m > n branch
        let b = Matrix::low_rank(20, 10, 3, &mut rng);
        let fa = factorize(&a, &cfg(3)).unwrap();
        let fb = factorize(&b, &cfg(3)).unwrap();
        let c = lowrank_matmul(&fa, &fb);
        assert!(c.rel_frobenius_distance(&a.matmul(&b)) < 1e-3);

        let a2 = Matrix::low_rank(10, 20, 3, &mut rng); // m <= n branch
        let b2 = Matrix::low_rank(20, 50, 3, &mut rng);
        let fa2 = factorize(&a2, &cfg(3)).unwrap();
        let fb2 = factorize(&b2, &cfg(3)).unwrap();
        let c2 = lowrank_matmul(&fa2, &fb2);
        assert!(c2.rel_frobenius_distance(&a2.matmul(&b2)) < 1e-3);
    }
}
