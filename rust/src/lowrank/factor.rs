//! Factorized matrix representation and decomposition configuration.

use crate::error::Result;
use crate::fp8::{dequantize, quantize, QuantizedTensor, StorageFormat};
use crate::linalg::matrix::Matrix;
use crate::linalg::rsvd::RsvdOptions;
use crate::lowrank::rank::RankStrategy;

/// Which decomposition algorithm produces the factors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecompMethod {
    /// Exact truncated SVD (one-sided Jacobi) — highest quality, O(mn²).
    ExactSvd,
    /// Randomized SVD (Halko) — the paper's default for large matrices.
    RandomizedSvd,
    /// Golub–Kahan–Lanczos bidiagonalization.
    Lanczos,
}

impl DecompMethod {
    /// Parse a config-file name.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "svd" | "exact" => DecompMethod::ExactSvd,
            "rsvd" | "randomized" => DecompMethod::RandomizedSvd,
            "lanczos" => DecompMethod::Lanczos,
            _ => return None,
        })
    }

    /// Human name.
    pub fn name(self) -> &'static str {
        match self {
            DecompMethod::ExactSvd => "svd",
            DecompMethod::RandomizedSvd => "rsvd",
            DecompMethod::Lanczos => "lanczos",
        }
    }
}

/// Full configuration for producing a [`LowRankFactor`].
#[derive(Clone, Debug)]
pub struct LowRankConfig {
    /// How the rank is chosen (paper §3.2).
    pub rank: RankStrategy,
    /// Which decomposition runs (paper §3.1).
    pub method: DecompMethod,
    /// Storage precision of U and Vᵀ (paper §3.3: FP8 storage).
    pub storage: StorageFormat,
    /// Randomized-SVD tuning.
    pub rsvd: RsvdOptions,
}

impl Default for LowRankConfig {
    fn default() -> Self {
        LowRankConfig {
            rank: RankStrategy::EnergyFraction(0.99),
            method: DecompMethod::RandomizedSvd,
            storage: StorageFormat::F32,
            rsvd: RsvdOptions::default(),
        }
    }
}

/// A matrix in factored form `A ≈ U · diag(s) · Vᵀ`, with U/Vᵀ optionally
/// held in reduced precision. Singular values are always f32: they are
/// `r` scalars, and keeping them exact is free and numerically important
/// (the paper's "FP32 accumulation" discipline applied to the spectrum).
#[derive(Clone, Debug)]
pub struct LowRankFactor {
    /// m×r left factor (quantized).
    pub u: QuantizedTensor,
    /// Singular values, length r.
    pub s: Vec<f32>,
    /// r×n right factor (quantized).
    pub vt: QuantizedTensor,
    /// Original shape of the dense matrix this approximates.
    pub orig_shape: (usize, usize),
    /// Decomposition that produced this factor.
    pub method: DecompMethod,
}

impl LowRankFactor {
    /// Build from dense SVD factors, quantizing to `storage`.
    pub fn from_svd(
        u: &Matrix,
        s: Vec<f32>,
        vt: &Matrix,
        storage: StorageFormat,
        orig_shape: (usize, usize),
        method: DecompMethod,
    ) -> Self {
        LowRankFactor {
            u: quantize(u, storage),
            s,
            vt: quantize(vt, storage),
            orig_shape,
            method,
        }
    }

    /// Retained rank.
    pub fn rank(&self) -> usize {
        self.s.len()
    }

    /// Dense U (dequantized).
    pub fn u_dense(&self) -> Matrix {
        dequantize(&self.u)
    }

    /// Dense Vᵀ (dequantized).
    pub fn vt_dense(&self) -> Matrix {
        dequantize(&self.vt)
    }

    /// Reconstruct the dense approximation `U diag(s) Vᵀ`.
    pub fn reconstruct(&self) -> Matrix {
        let mut u = self.u_dense();
        u.scale_cols_in_place(&self.s);
        u.matmul(&self.vt_dense())
    }

    /// Bytes used by the factorized storage (paper §5.3 accounting):
    /// `(m·r + r + r·n) × bytes_per_element`, with the spectrum charged at
    /// f32 width.
    pub fn storage_bytes(&self) -> usize {
        let (m, n) = self.orig_shape;
        let r = self.rank();
        let be = self.u.format.bytes_per_element();
        m * r * be + r * 4 + r * n * be
    }

    /// Bytes the dense matrix would use at the same storage precision.
    pub fn dense_bytes(&self) -> usize {
        let (m, n) = self.orig_shape;
        m * n * self.u.format.bytes_per_element()
    }

    /// Memory saving ratio `1 − factored/dense` (the paper's "75%").
    pub fn memory_saving(&self) -> f64 {
        1.0 - self.storage_bytes() as f64 / self.dense_bytes() as f64
    }

    /// Measured relative Frobenius error against the original dense matrix.
    pub fn measured_error(&self, original: &Matrix) -> f32 {
        self.reconstruct().rel_frobenius_distance(original)
    }

    /// The rank-sized core against another factor (paper Eq. 1):
    /// `core = diag(s_a) · (Vᵀ_a U_b) · diag(s_b)`, an `r_a × r_b` dense
    /// matrix. This is the only place the contracted dimension k appears;
    /// the backend ships it to the `lowrank_apply` artifact alongside
    /// `U_a` and `Vᵀ_b`.
    pub fn core_with(&self, other: &LowRankFactor) -> Result<Matrix> {
        if self.orig_shape.1 != other.orig_shape.0 {
            return Err(crate::error::Error::ShapeMismatch {
                op: "lowrank core",
                lhs: self.orig_shape,
                rhs: other.orig_shape,
            });
        }
        let vt_a = self.vt_dense();
        let u_b = other.u_dense();
        let mut core = vt_a.matmul(&u_b);
        core.scale_rows_in_place(&self.s);
        core.scale_cols_in_place(&other.s);
        Ok(core)
    }

    /// Apply to a dense vector: `y = U (s ⊙ (Vᵀ x))` without reconstructing.
    pub fn apply(&self, x: &[f32]) -> Result<Vec<f32>> {
        let vt = self.vt_dense();
        let u = self.u_dense();
        let mut core = vt.matvec(x);
        for (c, &s) in core.iter_mut().zip(&self.s) {
            *c *= s;
        }
        Ok(u.matvec(&core))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;
    use crate::linalg::svd::truncated_svd;

    fn factor_of(seed: u64, storage: StorageFormat) -> (Matrix, LowRankFactor) {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::low_rank_noisy(32, 24, 5, 1e-3, &mut rng);
        let svd = truncated_svd(&a, 5).unwrap();
        let f = LowRankFactor::from_svd(&svd.u, svd.s.clone(), &svd.vt, storage, a.shape(), DecompMethod::ExactSvd);
        (a, f)
    }

    #[test]
    fn reconstruct_close_to_original() {
        let (a, f) = factor_of(61, StorageFormat::F32);
        assert!(f.measured_error(&a) < 5e-3);
    }

    #[test]
    fn fp8_storage_degrades_gracefully() {
        let (a, f32f) = factor_of(62, StorageFormat::F32);
        let (_, f8) = factor_of(62, StorageFormat::Fp8(crate::fp8::Fp8Format::E4M3));
        let e32 = f32f.measured_error(&a);
        let e8 = f8.measured_error(&a);
        assert!(e8 > e32);
        assert!(e8 < 0.08, "fp8 factor err {e8}");
    }

    #[test]
    fn storage_accounting() {
        let (_, f) = factor_of(63, StorageFormat::Fp8(crate::fp8::Fp8Format::E4M3));
        let (m, n) = (32usize, 24usize);
        let r = 5usize;
        assert_eq!(f.storage_bytes(), m * r + r * 4 + r * n);
        assert_eq!(f.dense_bytes(), m * n);
        assert!(f.memory_saving() > 0.0);
    }

    #[test]
    fn paper_table2_memory_ratio() {
        // Paper §5.3: N=20480, r=512 factorized FP8 ≈ 21 MB/matrix vs
        // 419 MB dense FP8 → saving ≈ 95% per matrix; the "75%" headline
        // comes from workspace overheads modeled in gpu_sim. Here we check
        // the raw factor arithmetic the section states (~20.99 M elements).
        let (m, n, r) = (20480usize, 20480usize, 512usize);
        let elems = m * r + r + r * n;
        assert_eq!(elems, 20_971_520 + 512);
    }

    #[test]
    fn apply_matches_reconstruct_matvec() {
        let (_, f) = factor_of(64, StorageFormat::F32);
        let x: Vec<f32> = (0..24).map(|i| (i as f32 * 0.37).sin()).collect();
        let y1 = f.apply(&x).unwrap();
        let y2 = f.reconstruct().matvec(&x);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in [DecompMethod::ExactSvd, DecompMethod::RandomizedSvd, DecompMethod::Lanczos] {
            assert_eq!(DecompMethod::parse(m.name()), Some(m));
        }
        assert_eq!(DecompMethod::parse("qr"), None);
    }
}
