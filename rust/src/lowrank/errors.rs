//! Error bounds and measured-error helpers — paper §5.4.
//!
//! - Eckart–Young: the rank-r truncation error is exactly
//!   `sqrt(Σ_{j>r} σ_j²)` in Frobenius norm — the *best possible* for any
//!   rank-r factorization.
//! - The paper's §5.4.4 quotes a heuristic `ε ≈ sqrt(n/r)`-shaped scaling
//!   for well-conditioned matrices; [`predicted_rel_error`] implements it
//!   so the benchmarks can plot paper-prediction vs measured side by side
//!   (EXPERIMENTS.md records where the heuristic does and does not hold).

use crate::linalg::matrix::Matrix;

/// Exact Eckart–Young truncation error (absolute, Frobenius) for keeping
/// `r` of the given singular values.
pub fn eckart_young_error(sv: &[f32], r: usize) -> f32 {
    sv.iter()
        .skip(r)
        .map(|&s| (s as f64) * (s as f64))
        .sum::<f64>()
        .sqrt() as f32
}

/// Relative version: tail energy over total energy, as a Frobenius ratio.
pub fn eckart_young_rel_error(sv: &[f32], r: usize) -> f32 {
    let total: f64 = sv.iter().map(|&s| (s as f64) * (s as f64)).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let tail: f64 = sv
        .iter()
        .skip(r)
        .map(|&s| (s as f64) * (s as f64))
        .sum();
    (tail / total).sqrt() as f32
}

/// Fraction of spectral energy captured by the leading `r` values.
pub fn energy_capture(sv: &[f32], r: usize) -> f32 {
    let total: f64 = sv.iter().map(|&s| (s as f64) * (s as f64)).sum();
    if total <= 0.0 {
        return 1.0;
    }
    let head: f64 = sv
        .iter()
        .take(r)
        .map(|&s| (s as f64) * (s as f64))
        .sum();
    (head / total) as f32
}

/// The paper's §5.4.4 heuristic error model, `ε ≈ c · sqrt(n / r)` with the
/// constant calibrated so that the paper's own operating point
/// (N = 20480, r = 512 → ~1–2% error) is reproduced (c ≈ 0.0025).
///
/// Clamped to [0, 1]: a relative Frobenius error cannot meaningfully
/// exceed 1 (the zero matrix already achieves exactly 1), and the raw
/// heuristic blows past it once n/r crosses (1/c)² = 160 000 (e.g. r = 1
/// at n ≥ 2¹⁸), which would poison downstream tolerance math.
pub fn predicted_rel_error(n: usize, r: usize) -> f32 {
    const C: f32 = 0.0025;
    if r == 0 {
        return 1.0;
    }
    (C * ((n as f32) / (r as f32)).sqrt()).clamp(0.0, 1.0)
}

/// Measured relative Frobenius error between an approximation and the
/// exact product (convenience wrapper used by benches and examples).
pub fn measured_rel_error(approx: &Matrix, exact: &Matrix) -> f32 {
    approx.rel_frobenius_distance(exact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;
    use crate::linalg::svd::truncated_svd;

    #[test]
    fn eckart_young_known_values() {
        let sv = [3.0, 2.0, 1.0];
        assert!((eckart_young_error(&sv, 0) - (14.0f32).sqrt()).abs() < 1e-6);
        assert!((eckart_young_error(&sv, 2) - 1.0).abs() < 1e-6);
        assert_eq!(eckart_young_error(&sv, 3), 0.0);
    }

    #[test]
    fn relative_error_and_energy_are_complementary() {
        let sv = [4.0, 2.0, 1.0, 0.5];
        for r in 0..=4 {
            let e = eckart_young_rel_error(&sv, r);
            let g = energy_capture(&sv, r);
            assert!((e * e + g - 1.0).abs() < 1e-6, "r={r}");
        }
    }

    #[test]
    fn matches_measured_truncation_error() {
        let mut rng = Pcg64::seeded(81);
        let sv = [9.0, 4.0, 2.0, 1.0, 0.5, 0.25];
        let a = Matrix::with_spectrum(24, 20, &sv, &mut rng);
        let r = 3;
        let t = truncated_svd(&a, r).unwrap();
        let measured = t.reconstruct().sub(&a).unwrap().frobenius_norm();
        let predicted = eckart_young_error(&sv, r);
        assert!(
            (measured - predicted).abs() / predicted < 0.02,
            "measured {measured} predicted {predicted}"
        );
    }

    #[test]
    fn paper_heuristic_at_operating_point() {
        // N=20480, r=512 → ≈ 1.6% — inside the paper's "1-2%" band.
        let e = predicted_rel_error(20480, 512);
        assert!((0.01..=0.02).contains(&e), "e = {e}");
    }

    #[test]
    fn heuristic_monotonicity() {
        assert!(predicted_rel_error(4096, 64) > predicted_rel_error(4096, 256));
        assert!(predicted_rel_error(16384, 128) > predicted_rel_error(4096, 128));
    }

    #[test]
    fn heuristic_clamped_to_unit_interval() {
        // Regression: the unclamped heuristic exceeds 1.0 once n/r passes
        // (1/c)² = 160 000 — e.g. r = 1 at n ≥ 2¹⁸, where 0.0025·√(n/r)
        // = 1.28. A relative error above 1 is meaningless (the zero
        // matrix achieves exactly 1), so the model must saturate there.
        assert_eq!(predicted_rel_error(1 << 18, 1), 1.0);
        assert_eq!(predicted_rel_error(1 << 24, 4), 1.0);
        assert_eq!(predicted_rel_error(0, 5), 0.0);
        for (n, r) in [(16384, 4), (20480, 512), (1024, 1), (64, 64)] {
            let e = predicted_rel_error(n, r);
            assert!((0.0..=1.0).contains(&e), "e({n},{r}) = {e}");
        }
    }

    #[test]
    fn zero_spectrum_edge_cases() {
        assert_eq!(eckart_young_rel_error(&[], 0), 0.0);
        assert_eq!(energy_capture(&[], 3), 1.0);
        assert_eq!(eckart_young_rel_error(&[0.0, 0.0], 1), 0.0);
    }
}
