//! The factor-cache plane: content-addressed reuse of SVD/rSVD factors
//! across requests.
//!
//! The paper's speedup case rests on amortization — once a matrix is
//! decomposed, the factor chain `U·(Σ·(Vᵀ·B))` is far cheaper than a
//! dense GEMM — and §6.5 says the decomposition is "ideally computed in
//! advance". The id-keyed [`crate::lowrank::FactorCache`] covers callers
//! who can name their weights; this plane covers the serving reality
//! where repeated operands arrive *anonymous*: a [`Fingerprint`]
//! (shape + 128-bit content digest over exact f32 bit patterns) derives
//! a stable identity from the bytes themselves, and the [`ContentCache`]
//! holds the `(U, Σ, Vᵀ)` factors behind a byte-budgeted LRU.
//!
//! ```text
//!   route():  fp = Fingerprint::of(A)       — once, stashed in the plan
//!             factors_cached = cache.contains(fp)
//!             cost model amortizes the decomposition charge over
//!             [cache].amortize_over expected reuses
//!   execute(): cache.get_or_insert_with(fp, || rSVD on the shard plane)
//!              → factor chain through the panel-parallel paths
//! ```
//!
//! Interactions with the other planes:
//!
//! - **selector/cost** — a resident fingerprint flips `factors_cached`,
//!   pricing the request at factor-chain cost only; a *missing* one still
//!   divides the decomposition charge by the `[cache].amortize_over`
//!   knob (the amortized-decomposition term), which moves the low-rank
//!   crossover well below the paper's cold N ≥ 10240.
//! - **shard** — cold fills factorize via
//!   [`crate::shard::factorize_sharded`] and hits execute the chain
//!   through the same panel-parallel paths, so cached and cold results
//!   are bitwise identical.
//! - **fp8** — `[cache].fp8 = true` stores factors through the existing
//!   [`crate::fp8`] codecs (~75% resident-memory saving vs f32 factors);
//!   both the fill and every hit use the same storage, so hit/cold
//!   bit-identity is preserved.
//! - **pack** — `[cache].prepack = true` additionally stores each
//!   factor's `Vᵀ` pre-packed into the kernel panel layout
//!   ([`crate::linalg::pack::PackedB`]), so a hit's reconstruction
//!   product reads cached panels directly: no decode, no pack. Cold
//!   fills hand back the same shared panels, keeping hit ≡ cold bitwise.
//!
//! Default-off: with `[cache].enabled = false` nothing is fingerprinted,
//! the amortization term stays 1.0, and routing/execution are
//! bit-identical to a build without this module.
//!
//! Known limitations (deliberate, documented trade-offs):
//!
//! - **One-shot operands churn the LRU.** Every admitted anonymous miss
//!   is inserted, so a stream of never-repeating activations fills the
//!   budget and can evict reusable weights; `min_dim` and `budget_mb`
//!   are the levers today (a second-sighting doorkeeper would fix it but
//!   conflicts with "decompose each distinct matrix exactly once").
//!   The static `amortize_over` credit is likewise optimistic for
//!   operands that never recur.
//! - **The digest is not adversarial-grade** — see
//!   [`fingerprint`]'s module docs.

pub mod fingerprint;
pub mod store;

pub use fingerprint::{FactorHints, Fingerprint};
pub use store::{CachedFactor, ContentCache};
