//! Content-addressed matrix identity.
//!
//! The id-keyed [`crate::lowrank::FactorCache`] needs the caller to name
//! its weights; a serving front that only sees raw operands cannot. A
//! [`Fingerprint`] derives the identity from the matrix itself: the shape
//! plus a deterministic 128-bit digest of every element's exact bit
//! pattern (row-major `f32::to_bits` words) — `-0.0` vs `0.0`, NaN
//! payloads and all. Every content bit feeds the digest, so same-shape
//! matrices with different content alias only on a 128-bit hash
//! collision.
//!
//! Caveat on the digest: FNV-1a is fast and statistically well-spread
//! but **not collision-resistant against adversarial inputs** — an
//! attacker who controls operand bytes can construct colliding matrices,
//! and on a collision the cache would serve another matrix's factors as
//! a silently wrong result. The plane therefore assumes operands come
//! from the deployment itself (model weights, trusted callers), which is
//! the paper's serving setting; swap in a keyed cryptographic hash here
//! before exposing content-addressed caching to untrusted tenants.

use crate::linalg::matrix::Matrix;

/// 128-bit FNV-1a offset basis.
const FNV_OFFSET: u128 = 0x6c62_272e_07bb_0142_62b8_2175_6295_c58d;
/// 128-bit FNV-1a prime.
const FNV_PRIME: u128 = 0x0000_0000_0100_0000_0000_0000_0000_013b;

/// Content-addressed identity of a dense matrix: shape + content digest.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint {
    /// Row count of the fingerprinted matrix.
    pub rows: u32,
    /// Column count of the fingerprinted matrix.
    pub cols: u32,
    /// FNV-1a-128 digest over the row-major `f32` bit patterns.
    pub digest: u128,
}

impl Fingerprint {
    /// Fingerprint a matrix: one linear pass over the data (word-wise
    /// FNV-1a, ~O(mn)) — trivial next to the O(mnr) decomposition it
    /// stands in for, but not free: the router only computes it when the
    /// content cache is enabled and the operand clears the size gate.
    pub fn of(m: &Matrix) -> Fingerprint {
        let mut h = FNV_OFFSET;
        h = (h ^ m.rows() as u128).wrapping_mul(FNV_PRIME);
        h = (h ^ m.cols() as u128).wrapping_mul(FNV_PRIME);
        for &x in m.data() {
            h = (h ^ x.to_bits() as u128).wrapping_mul(FNV_PRIME);
        }
        Fingerprint {
            rows: m.rows() as u32,
            cols: m.cols() as u32,
            digest: h,
        }
    }

    /// The fingerprinted shape.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows as usize, self.cols as usize)
    }

    /// Byte width of the wire encoding ([`Self::to_wire_bytes`]).
    pub const WIRE_LEN: usize = 24;

    /// Stable wire encoding: `rows` (u32 LE) ‖ `cols` (u32 LE) ‖ `digest`
    /// (u128 LE), 24 bytes total. Fixed-width little-endian — independent
    /// of host endianness and struct layout — so fingerprints exchanged
    /// between cluster nodes compare equal iff the matrices do.
    pub fn to_wire_bytes(&self) -> [u8; Self::WIRE_LEN] {
        let mut out = [0u8; Self::WIRE_LEN];
        out[0..4].copy_from_slice(&self.rows.to_le_bytes());
        out[4..8].copy_from_slice(&self.cols.to_le_bytes());
        out[8..24].copy_from_slice(&self.digest.to_le_bytes());
        out
    }

    /// Inverse of [`Self::to_wire_bytes`].
    pub fn from_wire_bytes(b: &[u8; Self::WIRE_LEN]) -> Fingerprint {
        Fingerprint {
            rows: u32::from_le_bytes(b[0..4].try_into().expect("4 bytes")),
            cols: u32::from_le_bytes(b[4..8].try_into().expect("4 bytes")),
            digest: u128::from_le_bytes(b[8..24].try_into().expect("16 bytes")),
        }
    }
}

/// Routing-time fingerprints for one request's operands, computed once by
/// the router and handed to the backend through the plan so the execution
/// path never hashes an operand twice. `None` = not content-addressable
/// (identified operand, cache disabled, or below the size gate).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FactorHints {
    /// Fingerprint of the left operand, when content-addressable.
    pub a: Option<Fingerprint>,
    /// Fingerprint of the right operand, when content-addressable.
    pub b: Option<Fingerprint>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;

    #[test]
    fn deterministic_across_calls() {
        let mut rng = Pcg64::seeded(11);
        let a = Matrix::gaussian(17, 23, &mut rng);
        assert_eq!(Fingerprint::of(&a), Fingerprint::of(&a));
        assert_eq!(Fingerprint::of(&a), Fingerprint::of(&a.clone()));
    }

    #[test]
    fn same_shape_different_content_gets_distinct_digests() {
        // Every bit of content is digested, so same-shape matrices
        // differing anywhere get distinct keys (up to a 128-bit hash
        // collision — see the module docs' adversarial caveat).
        let mut rng = Pcg64::seeded(12);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..64 {
            let m = Matrix::gaussian(16, 16, &mut rng);
            assert!(seen.insert(Fingerprint::of(&m)), "collision");
        }
        // A single-ulp flip in one element changes the digest.
        let a = Matrix::gaussian(16, 16, &mut rng);
        let mut b = a.clone();
        let flipped = f32::from_bits(b.data()[7].to_bits() ^ 1);
        b.data_mut()[7] = flipped;
        assert_ne!(Fingerprint::of(&a), Fingerprint::of(&b));
    }

    #[test]
    fn shape_is_part_of_the_key() {
        // Same data vector, different shape → different fingerprint even
        // if the flat contents agree.
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let a = Matrix::from_vec(3, 4, data.clone()).unwrap();
        let b = Matrix::from_vec(4, 3, data).unwrap();
        assert_ne!(Fingerprint::of(&a), Fingerprint::of(&b));
        assert_eq!(Fingerprint::of(&a).shape(), (3, 4));
    }

    #[test]
    fn sign_of_zero_and_nan_bits_distinguish() {
        let a = Matrix::from_vec(1, 2, vec![0.0, 1.0]).unwrap();
        let b = Matrix::from_vec(1, 2, vec![-0.0, 1.0]).unwrap();
        assert_ne!(Fingerprint::of(&a), Fingerprint::of(&b));
    }

    #[test]
    fn wire_round_trip_preserves_identity() {
        let mut rng = Pcg64::seeded(14);
        for _ in 0..32 {
            let m = Matrix::gaussian(9, 13, &mut rng);
            let fp = Fingerprint::of(&m);
            assert_eq!(Fingerprint::from_wire_bytes(&fp.to_wire_bytes()), fp);
        }
    }

    #[test]
    fn wire_encoding_is_stable_little_endian() {
        // The encoding is a wire contract between cluster peers: pin the
        // exact bytes so a layout or endianness regression is caught here
        // rather than as cross-node cache misses.
        let fp = Fingerprint {
            rows: 0x0102_0304,
            cols: 0x0506_0708,
            digest: 0x0910_1112_1314_1516_1718_1920_2122_2324,
        };
        let w = fp.to_wire_bytes();
        assert_eq!(&w[0..4], &[0x04, 0x03, 0x02, 0x01]);
        assert_eq!(&w[4..8], &[0x08, 0x07, 0x06, 0x05]);
        assert_eq!(
            &w[8..24],
            &[
                0x24, 0x23, 0x22, 0x21, 0x20, 0x19, 0x18, 0x17, 0x16, 0x15, 0x14, 0x13,
                0x12, 0x11, 0x10, 0x09
            ]
        );
        assert_eq!(Fingerprint::from_wire_bytes(&w), fp);
    }

    #[test]
    fn transpose_differs() {
        let mut rng = Pcg64::seeded(13);
        let a = Matrix::gaussian(8, 8, &mut rng);
        assert_ne!(Fingerprint::of(&a), Fingerprint::of(&a.transpose()));
    }
}
