//! The content-addressed factor store: a byte-budgeted LRU over
//! [`Fingerprint`]-keyed [`LowRankFactor`]s, with live hit/miss/evict
//! metrics.
//!
//! Shape mirrors [`crate::lowrank::FactorCache`] (the id-keyed plane):
//! byte-budgeted rather than entry-budgeted because factor size varies
//! with rank, single mutex because the critical sections are a hash probe
//! next to millisecond GEMMs. What's new here is the admission gate
//! (operands below `min_dim` are never worth hashing or caching — their
//! decomposition is cheaper than the bookkeeping) and the metrics hookup:
//! every lookup/insert/eviction lands in the shared [`MetricsRegistry`]
//! as `cache.hit` / `cache.miss` / `cache.insert` / `cache.evict`
//! counters plus a `cache.resident_bytes` gauge-style histogram, so the
//! serving report shows the plane's behavior without polling.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::cache::fingerprint::Fingerprint;
use crate::linalg::gemm::kernel_params;
use crate::linalg::matrix::Matrix;
use crate::linalg::pack::PackedB;
use crate::lowrank::cache::CacheStats;
use crate::lowrank::factor::LowRankFactor;
use crate::metrics::{Counter, HistogramHandle, MetricsRegistry};

/// Interned handles for the plane's metrics, resolved once at cache
/// construction so lookups never hash a metric name.
struct CacheMetrics {
    hit: Arc<Counter>,
    miss: Arc<Counter>,
    insert: Arc<Counter>,
    evict: Arc<Counter>,
    prepacked_hit: Arc<Counter>,
    resident_bytes: Arc<HistogramHandle>,
}

impl CacheMetrics {
    fn new(registry: &MetricsRegistry) -> Self {
        CacheMetrics {
            hit: registry.counter("cache.hit"),
            miss: registry.counter("cache.miss"),
            insert: registry.counter("cache.insert"),
            evict: registry.counter("cache.evict"),
            prepacked_hit: registry.counter("pack.prepacked_hit"),
            resident_bytes: registry.histogram("cache.resident_bytes"),
        }
    }
}

struct Entry {
    factor: LowRankFactor,
    /// `Vᵀ` pre-packed into the kernel's panel layout (the `[cache]
    /// prepack` option): a hit hands the factor chain ready-to-multiply
    /// panels, skipping both the decode and the pack of the
    /// reconstruction operand.
    packed_vt: Option<Arc<PackedB>>,
    bytes: usize,
    last_used: u64,
}

/// A cache lookup result: the factor plus its pre-packed `Vᵀ` panels when
/// the store keeps them (see [`ContentCache::with_prepack`]).
pub struct CachedFactor {
    /// The low-rank factor (cloned out of the store).
    pub factor: LowRankFactor,
    /// Shared pre-packed `Vᵀ_B` panels, `None` when prepacking is off.
    pub packed_vt: Option<Arc<PackedB>>,
}

struct Inner {
    map: HashMap<Fingerprint, Entry>,
    clock: u64,
    resident: usize,
    stats: CacheStats,
}

/// Thread-safe, byte-budgeted, content-addressed LRU factor cache.
pub struct ContentCache {
    budget_bytes: usize,
    min_dim: usize,
    prepack: bool,
    metrics: Option<CacheMetrics>,
    inner: Mutex<Inner>,
}

impl ContentCache {
    /// Create a cache with a byte budget and an admission gate: only
    /// matrices with `min(rows, cols) >= min_dim` are fingerprinted and
    /// cached.
    pub fn new(budget_bytes: usize, min_dim: usize) -> Self {
        ContentCache {
            budget_bytes,
            min_dim,
            prepack: false,
            metrics: None,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                clock: 0,
                resident: 0,
                stats: CacheStats::default(),
            }),
        }
    }

    /// Like [`new`](ContentCache::new), wired to a metrics registry.
    pub fn with_metrics(
        budget_bytes: usize,
        min_dim: usize,
        metrics: Arc<MetricsRegistry>,
    ) -> Self {
        let mut c = Self::new(budget_bytes, min_dim);
        c.metrics = Some(CacheMetrics::new(&metrics));
        c
    }

    /// Builder: also store each factor's `Vᵀ` pre-packed into the current
    /// kernel panel layout (`[cache] prepack`), so a hit skips the
    /// reconstruction operand's decode-and-pack entirely. The packed
    /// panels are charged against the byte budget (f32 panels: `r·n·4`
    /// bytes on top of the factor's own storage).
    pub fn with_prepack(mut self, prepack: bool) -> Self {
        self.prepack = prepack;
        self
    }

    /// Does the admission gate let this operand into the cache?
    pub fn admits(&self, m: &Matrix) -> bool {
        m.rows().min(m.cols()) >= self.min_dim
    }

    /// The admission gate's dimension floor.
    pub fn min_dim(&self) -> usize {
        self.min_dim
    }

    /// Look up a factor; clones on hit (the payload must cross the worker
    /// boundary anyway).
    pub fn get(&self, fp: Fingerprint) -> Option<LowRankFactor> {
        self.lookup(fp, false).map(|c| c.factor)
    }

    /// [`get`](ContentCache::get) returning the pre-packed `Vᵀ` panels as
    /// well (shared `Arc` — no payload copy) when the store keeps them.
    pub fn get_cached(&self, fp: Fingerprint) -> Option<CachedFactor> {
        self.lookup(fp, true)
    }

    /// Shared lookup. `want_packed` gates both the panel hand-out and the
    /// `pack.prepacked_hit` counter: callers that immediately drop the
    /// panels (A-side factor fetches) must not inflate the metric an
    /// operator compares against `pack.prepacked_use`.
    fn lookup(&self, fp: Fingerprint, want_packed: bool) -> Option<CachedFactor> {
        let out = {
            let mut g = self.inner.lock().unwrap();
            g.clock += 1;
            let clock = g.clock;
            match g.map.get_mut(&fp) {
                Some(e) => {
                    e.last_used = clock;
                    let f = CachedFactor {
                        factor: e.factor.clone(),
                        packed_vt: if want_packed {
                            e.packed_vt.clone()
                        } else {
                            None
                        },
                    };
                    g.stats.hits += 1;
                    Some(f)
                }
                None => {
                    g.stats.misses += 1;
                    None
                }
            }
        };
        if let Some(m) = &self.metrics {
            match &out {
                Some(c) => {
                    m.hit.inc();
                    if c.packed_vt.is_some() {
                        m.prepacked_hit.inc();
                    }
                }
                None => m.miss.inc(),
            }
        }
        out
    }

    /// Presence probe that neither clones nor perturbs LRU order or
    /// hit/miss accounting (the router only *plans*).
    pub fn contains(&self, fp: Fingerprint) -> bool {
        self.inner.lock().unwrap().map.contains_key(&fp)
    }

    /// Insert (or replace) a factor, evicting LRU entries until it fits.
    /// Factors larger than the whole budget are rejected (returns false).
    /// With prepacking on, `Vᵀ` is decoded into the kernel panel layout
    /// once here (fill time), and its f32 panels count against the budget.
    pub fn put(&self, fp: Fingerprint, factor: LowRankFactor) -> bool {
        // Estimate the entry (factor + r·n·4 f32 panels) *before* doing
        // any packing work: an oversized factor must be rejected without
        // paying the decode-and-pack pass it would throw away.
        let (vt_rows, vt_cols) = factor.vt.shape;
        let est_packed = if self.prepack {
            vt_rows * vt_cols * std::mem::size_of::<f32>()
        } else {
            0
        };
        if factor.storage_bytes() + est_packed > self.budget_bytes {
            return false;
        }
        let packed_vt = if self.prepack {
            let p = kernel_params();
            let mut pb = PackedB::pack_quantized(&factor.vt, p.kc, p.nc);
            // The pack buffer is an arena checkout whose capacity may
            // exceed r·n; a resident entry must not pin the slack.
            pb.shrink_to_fit();
            Some(Arc::new(pb))
        } else {
            None
        };
        // Charge what the entry actually pins — the packed buffer's
        // post-shrink *capacity*, not the r·n estimate — so the byte
        // budget stays honest and eviction releases exactly what
        // insertion charged. The allocator has the last word on shrink,
        // so re-check the budget against the real footprint.
        let bytes = factor.storage_bytes()
            + packed_vt.as_ref().map_or(0, |p| p.resident_bytes());
        if bytes > self.budget_bytes {
            return false;
        }
        let (evicted, resident) = {
            let mut g = self.inner.lock().unwrap();
            g.clock += 1;
            let clock = g.clock;
            if let Some(old) = g.map.remove(&fp) {
                g.resident -= old.bytes;
            }
            let mut evicted = 0u64;
            while g.resident + bytes > self.budget_bytes {
                let victim = g
                    .map
                    .iter()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(&k, _)| k);
                match victim {
                    Some(k) => {
                        let e = g.map.remove(&k).unwrap();
                        g.resident -= e.bytes;
                        g.stats.evictions += 1;
                        evicted += 1;
                    }
                    None => break,
                }
            }
            g.resident += bytes;
            g.map.insert(
                fp,
                Entry {
                    factor,
                    packed_vt,
                    bytes,
                    last_used: clock,
                },
            );
            g.stats.resident_bytes = g.resident as u64;
            g.stats.entries = g.map.len() as u64;
            (evicted, g.resident)
        };
        if let Some(m) = &self.metrics {
            m.insert.inc();
            m.evict.add(evicted);
            m.resident_bytes.observe(resident as f64);
        }
        true
    }

    /// Fetch-or-compute. Single-flight is deliberately omitted (same call
    /// as the id-keyed cache): duplicate computes under concurrency are
    /// benign and both produce bit-identical factors.
    pub fn get_or_insert_with(
        &self,
        fp: Fingerprint,
        make: impl FnOnce() -> crate::error::Result<LowRankFactor>,
    ) -> crate::error::Result<LowRankFactor> {
        // Deliberately the non-packed lookup: this path's callers drop
        // the panels, so it must not count `pack.prepacked_hit`.
        if let Some(c) = self.lookup(fp, false) {
            return Ok(c.factor);
        }
        let f = make()?;
        self.put(fp, f.clone());
        Ok(f)
    }

    /// [`get_or_insert_with`](ContentCache::get_or_insert_with) that also
    /// returns the pre-packed `Vᵀ` panels. A cold fill hands back the
    /// panels it just built, so miss and hit requests run the exact same
    /// (prepacked) reconstruction path — hit ≡ cold stays bitwise.
    pub fn get_or_insert_with_packed(
        &self,
        fp: Fingerprint,
        make: impl FnOnce() -> crate::error::Result<LowRankFactor>,
    ) -> crate::error::Result<CachedFactor> {
        if let Some(c) = self.get_cached(fp) {
            return Ok(c);
        }
        let f = make()?;
        self.put(fp, f.clone());
        // Re-read so the cold fill serves the same shared panels a later
        // hit will (put may also have been rejected as oversized — then
        // there are simply no panels to share).
        let packed_vt = self
            .inner
            .lock()
            .unwrap()
            .map
            .get(&fp)
            .and_then(|e| e.packed_vt.clone());
        Ok(CachedFactor {
            factor: f,
            packed_vt,
        })
    }

    /// Up to `cap` resident fingerprints, most-recently-used first — the
    /// cluster heartbeat's cache-occupancy digest. Does not perturb LRU
    /// order or hit/miss accounting.
    pub fn resident_fingerprints(&self, cap: usize) -> Vec<Fingerprint> {
        let g = self.inner.lock().unwrap();
        let mut entries: Vec<(&Fingerprint, u64)> =
            g.map.iter().map(|(fp, e)| (fp, e.last_used)).collect();
        entries.sort_by(|a, b| b.1.cmp(&a.1));
        entries.into_iter().take(cap).map(|(fp, _)| *fp).collect()
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        let mut g = self.inner.lock().unwrap();
        g.stats.resident_bytes = g.resident as u64;
        g.stats.entries = g.map.len() as u64;
        g.stats
    }

    /// Drop everything (tests / reconfiguration).
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.map.clear();
        g.resident = 0;
        g.stats.resident_bytes = 0;
        g.stats.entries = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::StorageFormat;
    use crate::linalg::rng::Pcg64;
    use crate::lowrank::factor::{DecompMethod, LowRankConfig};
    use crate::lowrank::gemm::factorize;
    use crate::lowrank::rank::RankStrategy;

    fn factor_and_fp(seed: u64, n: usize, r: usize) -> (Fingerprint, LowRankFactor) {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::low_rank(n, n, r, &mut rng);
        let f = factorize(
            &a,
            &LowRankConfig {
                rank: RankStrategy::Fixed(r),
                method: DecompMethod::RandomizedSvd,
                storage: StorageFormat::F32,
                ..Default::default()
            },
        )
        .unwrap();
        (Fingerprint::of(&a), f)
    }

    #[test]
    fn hit_after_put_and_stats() {
        let c = ContentCache::new(1 << 20, 1);
        let (fp, f) = factor_and_fp(1, 16, 2);
        assert!(c.get(fp).is_none());
        assert!(c.put(fp, f));
        assert!(c.get(fp).is_some());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn lru_evicts_strictly_by_byte_budget() {
        let (fp1, f) = factor_and_fp(2, 16, 2);
        let (fp2, _) = factor_and_fp(3, 16, 2);
        let (fp3, _) = factor_and_fp(4, 16, 2);
        let bytes = f.storage_bytes();
        // Budget for exactly two entries.
        let c = ContentCache::new(2 * bytes + bytes / 2, 1);
        c.put(fp1, f.clone());
        c.put(fp2, f.clone());
        assert_eq!(c.stats().resident_bytes, 2 * bytes as u64);
        c.get(fp1); // fp2 becomes LRU
        c.put(fp3, f.clone());
        assert!(c.contains(fp1), "recently used survives");
        assert!(!c.contains(fp2), "LRU evicted");
        assert!(c.contains(fp3));
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert!(
            s.resident_bytes <= 2 * bytes as u64 + bytes as u64 / 2,
            "budget respected: {} resident",
            s.resident_bytes
        );
    }

    #[test]
    fn oversized_factor_rejected() {
        let (fp, f) = factor_and_fp(5, 32, 4);
        let c = ContentCache::new(f.storage_bytes() - 1, 1);
        assert!(!c.put(fp, f));
        assert_eq!(c.stats().entries, 0);
    }

    #[test]
    fn admission_gate() {
        let c = ContentCache::new(1 << 20, 64);
        let mut rng = Pcg64::seeded(6);
        assert!(!c.admits(&Matrix::gaussian(63, 512, &mut rng)));
        assert!(c.admits(&Matrix::gaussian(64, 64, &mut rng)));
    }

    #[test]
    fn contains_does_not_perturb_stats_or_lru() {
        let c = ContentCache::new(1 << 20, 1);
        let (fp, f) = factor_and_fp(7, 16, 2);
        c.put(fp, f);
        for _ in 0..5 {
            assert!(c.contains(fp));
        }
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (0, 0));
    }

    #[test]
    fn metrics_counters_emitted() {
        let m = Arc::new(MetricsRegistry::new());
        let c = ContentCache::with_metrics(1 << 20, 1, m.clone());
        let (fp, f) = factor_and_fp(8, 16, 2);
        c.get(fp);
        c.put(fp, f);
        c.get(fp);
        let counters = m.counters();
        assert_eq!(counters["cache.miss"], 1);
        assert_eq!(counters["cache.hit"], 1);
        assert_eq!(counters["cache.insert"], 1);
        assert!(m
            .histogram_summaries()
            .contains_key("cache.resident_bytes"));
    }

    #[test]
    fn get_or_insert_computes_once() {
        let c = ContentCache::new(1 << 20, 1);
        let (fp, f) = factor_and_fp(9, 16, 2);
        let mut computed = 0;
        for _ in 0..3 {
            c.get_or_insert_with(fp, || {
                computed += 1;
                Ok(f.clone())
            })
            .unwrap();
        }
        assert_eq!(computed, 1);
        assert_eq!(c.stats().hits, 2);
    }

    #[test]
    fn prepack_stores_and_serves_shared_panels() {
        let c = ContentCache::new(1 << 20, 1).with_prepack(true);
        let (fp, f) = factor_and_fp(11, 32, 4);
        assert!(c.put(fp, f.clone()));
        let hit = c.get_cached(fp).expect("hit");
        let pb = hit.packed_vt.expect("prepacked panels stored");
        assert_eq!((pb.k(), pb.n()), f.vt.shape);
        // Panels hold exactly the decoded Vᵀ values.
        let vt = f.vt_dense();
        let unfused = crate::linalg::pack::PackedB::pack(&vt, pb.kc(), pb.nc());
        assert_eq!(pb.panel(0, 0), unfused.panel(0, 0));
        // Packed panels are charged against the budget at their actual
        // (post-shrink capacity) footprint, never below the r·n·4 data.
        let extra = pb.resident_bytes();
        assert!(extra >= pb.k() * pb.n() * 4);
        assert_eq!(
            c.stats().resident_bytes,
            (f.storage_bytes() + extra) as u64
        );
        // Cold fills hand back the same shared panels.
        let (fp2, f2) = factor_and_fp(12, 32, 4);
        let cold = c.get_or_insert_with_packed(fp2, || Ok(f2)).unwrap();
        assert!(cold.packed_vt.is_some());
    }

    #[test]
    fn prepack_off_keeps_entries_panel_free() {
        let c = ContentCache::new(1 << 20, 1);
        let (fp, f) = factor_and_fp(13, 16, 2);
        c.put(fp, f.clone());
        assert!(c.get_cached(fp).unwrap().packed_vt.is_none());
        assert_eq!(c.stats().resident_bytes, f.storage_bytes() as u64);
    }

    #[test]
    fn prepack_accounting_never_drifts_under_churn() {
        let (_, probe) = factor_and_fp(20, 32, 4);
        // Budget for ~3 prepacked entries; 12 inserts force eviction churn.
        let per = probe.storage_bytes() + 32 * 32 * 4;
        let c = ContentCache::new(3 * per + per / 2, 1).with_prepack(true);
        for seed in 0..12u64 {
            let (fp, f) = factor_and_fp(100 + seed, 32, 4);
            assert!(c.put(fp, f));
        }
        let s = c.stats();
        assert!(s.evictions > 0, "churn must actually evict");
        // Drift invariant: after arbitrary insert/evict interleaving the
        // byte gauge equals the sum of the survivors' true footprints —
        // evictions released exactly what insertions charged.
        let survivors = c.resident_fingerprints(usize::MAX);
        let mut expect = 0u64;
        for fp in &survivors {
            let hit = c.get_cached(*fp).expect("resident");
            expect += (hit.factor.storage_bytes()
                + hit.packed_vt.map_or(0, |p| p.resident_bytes()))
                as u64;
        }
        assert_eq!(c.stats().resident_bytes, expect);
    }

    #[test]
    fn resident_fingerprints_lists_mru_first_without_perturbing() {
        let c = ContentCache::new(1 << 20, 1);
        let (fp1, f1) = factor_and_fp(30, 16, 2);
        let (fp2, f2) = factor_and_fp(31, 16, 2);
        c.put(fp1, f1);
        c.put(fp2, f2);
        c.get(fp1); // fp1 becomes MRU
        let before = c.stats();
        let digest = c.resident_fingerprints(8);
        assert_eq!(digest, vec![fp1, fp2]);
        assert_eq!(c.resident_fingerprints(1), vec![fp1]);
        let after = c.stats();
        assert_eq!((before.hits, before.misses), (after.hits, after.misses));
    }

    #[test]
    fn clear_resets() {
        let c = ContentCache::new(1 << 20, 1);
        let (fp, f) = factor_and_fp(10, 16, 2);
        c.put(fp, f);
        c.clear();
        assert_eq!(c.stats().entries, 0);
        assert_eq!(c.stats().resident_bytes, 0);
        assert!(!c.contains(fp));
    }
}
