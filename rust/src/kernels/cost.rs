//! Analytic cost model feeding the selector.
//!
//! Wraps the roofline pipelines of [`crate::gpu_sim::roofline`] into a
//! per-kernel estimate for arbitrary (m, k, n) shapes, adding the
//! factorization charge when factors are not cached. Square-shape costs
//! delegate to the same code paths the benchmarks use, so the selector's
//! view of the world and the reported numbers can never diverge.
//!
//! Since the packed-operand hot path (PR 5), the dense f32 kernel carries
//! an explicit packing-bandwidth term (one f32 write per operand element,
//! paid once per GEMM thanks to pack-once/reuse-many) — and the f16/FP8
//! and factor-chain kernels, whose codec decode is fused into that same
//! write, don't, which is how the selector and the autotune plane see the
//! fused paths' bandwidth advantage.

use crate::gpu_sim::profile::{DeviceProfile, Precision};
use crate::gpu_sim::roofline::{OpCost, Roofline};
use crate::kernels::selector::{KernelKind, SelectorInputs};
use crate::shard::ShardPlan;

/// Predicted cost of running one kernel on one request.
#[derive(Clone, Copy, Debug)]
pub struct CostEstimate {
    /// Predicted wall time on the device (seconds).
    pub time_s: f64,
    /// Model FLOPs.
    pub flops: f64,
    /// Model bytes moved.
    pub bytes: f64,
}

/// Cost of `kind` on the request described by `inp`.
///
/// Calibration matches the Roofline pipelines exactly (the selector and
/// the Table-1 benchmarks must agree on who wins where): storage
/// precision sets the byte width, compute runs at the *kernel's* math
/// precision — f16 for every fp8-storage kernel ("FP8 storage, FP16
/// compute"), f32 for the SVD-class factorization stages of LowRankFp8,
/// f16 for LowRankAuto's TensorCore factorization.
pub fn kernel_cost(device: &DeviceProfile, kind: KernelKind, inp: &SelectorInputs) -> CostEstimate {
    let rl = Roofline::new(device.clone());
    let (m, k, n) = (inp.m as f64, inp.k as f64, inp.n as f64);
    let r = inp.rank.max(1) as f64;
    let be = kind.storage().bytes_per_element() as f64;
    let p = kind.compute_precision();

    let (time_s, cost) = match kind {
        KernelKind::DenseF32 | KernelKind::DenseF16 | KernelKind::DenseFp8 => {
            let quant_passes = if kind == KernelKind::DenseFp8 { 1.0 } else { 0.0 };
            // Packed-operand term (PR 5): both operands are packed once
            // into panel layout — a 4-byte (f32) write per element,
            // amortized across the whole tile grid by pack-once/reuse-
            // many. Every reduced-precision dense kernel (f16 and fp8
            // alike — both run `ShardExecutor::quantized_matmul`'s fused
            // branch) *fuses* the codec decode into that same write
            // (decode-into-pack), so only the f32 kernel, whose operands
            // arrive already dense, pays a separate pack pass — which is
            // exactly why the model now prices the fused paths (and,
            // below, the factor chain) relatively cheaper than dense f32.
            let pack_bytes = if kind == KernelKind::DenseF32 {
                (m * k + k * n) * 4.0
            } else {
                0.0
            };
            let c = OpCost {
                flops: 2.0 * m * k * n + quant_passes * (m * k + k * n),
                bytes: (m * k + k * n + m * n) * be
                    + quant_passes * (m * k + k * n) * (4.0 + be)
                    + pack_bytes,
                launches: 1.0 + 2.0 * quant_passes,
            };
            (rl.time(&c, p), c)
        }
        KernelKind::LowRankFp8 | KernelKind::LowRankAuto => {
            // Factor-chain flops (see lowrank::gemm::lowrank_flops).
            let chain_full = 2.0 * r * k * r + 2.0 * r * r + 2.0 * r * r * n + 2.0 * m * r * n;
            let (flops, bytes) = if kind == KernelKind::LowRankAuto && inp.factored_output_ok {
                // Factored output: skip the m×n materialization — its
                // rank-domain products sit below the packing cutover, so
                // no pack pass is charged either.
                (
                    2.0 * r * k * r + 2.0 * r * r + 2.0 * r * r * n + 2.0 * m * r * r,
                    ((m + k) * r + (k + n) * r + (m + n) * r) * be,
                )
            } else {
                // Materializing chain: charge the pack pass of the m×n
                // reconstruction's operands (U_A and Vᵀ_B panels, f32
                // writes). Pre-packed cache hits (`[cache] prepack`) skip
                // the Vᵀ_B share at run time; the model keeps the
                // conservative full charge.
                (
                    chain_full,
                    ((m + k) * r + (k + n) * r) * be + m * n * be + (m * r + r * n) * 4.0,
                )
            };
            let chain = OpCost {
                flops,
                bytes,
                launches: 4.0,
            };
            let mut t = rl.time(&chain, Precision::F16);
            let mut total = chain;
            if !inp.factors_cached {
                // Charge two randomized factorizations (both operands);
                // 5 passes (q=2 power iterations) + pipeline overhead.
                // LowRankFp8 factorizes in f32; Auto sketches on
                // TensorCores in f16 — same split as the Roofline model.
                //
                // Amortized-decomposition term (factor-cache plane): the
                // time charge is divided by the expected reuse count —
                // when the operands will land in a cache, the workload
                // pays the decomposition once and serves many requests
                // off the factors. `flops`/`bytes` stay the full miss
                // cost (they describe the work a miss actually does);
                // only the routing-relevant wall-time is amortized. At
                // the default amortization of 1.0 the division is an
                // exact identity, keeping cache-off routing bit-identical.
                let amort = inp.decomp_amortization.max(1.0);
                let fact_p = if kind == KernelKind::LowRankAuto {
                    Precision::F16
                } else {
                    Precision::F32
                };
                let l = r + 8.0;
                for (rows, cols) in [(m, k), (k, n)] {
                    let f = OpCost {
                        flops: 5.0 * (2.0 * rows * cols * l) + 8.0 * (rows + cols) * l * l,
                        bytes: 5.0 * rows * cols * be,
                        launches: Roofline::SVD_PIPELINE_LAUNCHES,
                    };
                    t += rl.time(&f, fact_p) / amort;
                    total = total.then(f);
                }
            }
            (t, total)
        }
    };

    CostEstimate {
        time_s,
        flops: cost.flops,
        bytes: cost.bytes,
    }
}

/// Modeled wall-clock speedup of running `kind` on the shard plane under
/// `plan`, Amdahl-style: the tileable phase scales with the effective
/// worker count (capped by the tile count), the sequential phase does not.
///
/// Sequential fractions per kernel class (measured on the CPU substrate):
/// dense pays only packing/assembly; FP8 adds the two codec round-trip
/// passes; the factor chain adds the rank-sized products, and a cold
/// factorization adds the QR/small-SVD stages of the panel-parallel rSVD.
///
/// Returns 1.0 whenever the plan's gates keep the request single-threaded,
/// so the selector's view matches the executor's routing exactly.
///
/// Caveat: the term models the CPU tile plane. Requests that land on an
/// AOT artifact (square lattice shapes with XLA configured) execute
/// off-plane, yet are discounted the same way — acceptable while the
/// artifact lattice is sparse, but worth revisiting if the XLA path
/// starts serving a meaningful share of traffic.
pub fn parallel_speedup(kind: KernelKind, inp: &SelectorInputs, plan: &ShardPlan) -> f64 {
    if !plan.should_parallelize(inp.m, inp.n, inp.k) {
        return 1.0;
    }
    let tiles = plan.grid.tile_count(inp.m, inp.n).max(1);
    let w = plan.workers.clamp(1, tiles) as f64;
    let serial_fraction = match kind {
        KernelKind::DenseF32 | KernelKind::DenseF16 => 0.05,
        KernelKind::DenseFp8 => 0.10,
        KernelKind::LowRankFp8 | KernelKind::LowRankAuto => {
            if inp.factors_cached {
                0.15
            } else {
                0.30
            }
        }
    };
    1.0 / (serial_fraction + (1.0 - serial_fraction) / w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_sim::profile::DeviceProfile;

    fn inp(n: usize, rank: usize, cached: bool) -> SelectorInputs {
        SelectorInputs {
            m: n,
            k: n,
            n,
            error_tolerance: 1.0,
            rank,
            factors_cached: cached,
            factored_output_ok: true,
            decomp_amortization: 1.0,
            fp8_reencode: false,
        }
    }

    #[test]
    fn dense_f16_cheaper_than_f32_at_scale() {
        let d = DeviceProfile::rtx4090();
        let a = kernel_cost(&d, KernelKind::DenseF32, &inp(8192, 0, true));
        let b = kernel_cost(&d, KernelKind::DenseF16, &inp(8192, 0, true));
        assert!(b.time_s < a.time_s);
    }

    #[test]
    fn lowrank_flops_sublinear_in_n3() {
        let d = DeviceProfile::rtx4090();
        let small = kernel_cost(&d, KernelKind::LowRankFp8, &inp(4096, 128, true));
        let big = kernel_cost(&d, KernelKind::LowRankFp8, &inp(8192, 128, true));
        // Dense scales 8x; low-rank with fixed r should scale ~4x or less
        // in flops (dominated by m·r·n).
        assert!(big.flops / small.flops < 5.0);
    }

    #[test]
    fn uncached_costs_more() {
        let d = DeviceProfile::rtx4090();
        let warm = kernel_cost(&d, KernelKind::LowRankFp8, &inp(4096, 128, true));
        let cold = kernel_cost(&d, KernelKind::LowRankFp8, &inp(4096, 128, false));
        assert!(cold.time_s > warm.time_s);
        assert!(cold.flops > warm.flops);
    }

    #[test]
    fn amortization_discounts_only_the_decomposition() {
        let d = DeviceProfile::rtx4090();
        let mut cold = inp(4096, 128, false);
        let full = kernel_cost(&d, KernelKind::LowRankFp8, &cold);
        cold.decomp_amortization = 8.0;
        let amortized = kernel_cost(&d, KernelKind::LowRankFp8, &cold);
        let warm = kernel_cost(&d, KernelKind::LowRankFp8, &inp(4096, 128, true));
        // Strictly between warm (no charge) and cold (full charge).
        assert!(amortized.time_s < full.time_s);
        assert!(amortized.time_s > warm.time_s);
        // The amortized decomposition charge is the cold charge / 8.
        let full_decomp = full.time_s - warm.time_s;
        let amort_decomp = amortized.time_s - warm.time_s;
        assert!(
            (amort_decomp - full_decomp / 8.0).abs() < full_decomp * 1e-12,
            "amortized {amort_decomp} vs {full_decomp}/8"
        );
        // Flops/bytes describe the miss's real work — not amortized.
        assert_eq!(amortized.flops, full.flops);
        assert_eq!(amortized.bytes, full.bytes);
        // Cached requests never charge a decomposition to amortize.
        let mut warm_inp = inp(4096, 128, true);
        warm_inp.decomp_amortization = 8.0;
        let warm8 = kernel_cost(&d, KernelKind::LowRankFp8, &warm_inp);
        assert_eq!(warm8.time_s.to_bits(), warm.time_s.to_bits());
    }

    #[test]
    fn packing_term_charges_only_the_unfused_f32_kernel() {
        let d = DeviceProfile::rtx4090();
        let n = 4096.0f64;
        let i = inp(4096, 0, true);
        let f32c = kernel_cost(&d, KernelKind::DenseF32, &i);
        // Dense f32: 3 operand passes at 4 B plus the 2-operand pack pass.
        assert_eq!(f32c.bytes, 3.0 * n * n * 4.0 + 2.0 * n * n * 4.0);
        // FP8: decode fused into the pack write — no separate pack term;
        // bytes are exactly the operand traffic + the encode round-trip.
        let fp8c = kernel_cost(&d, KernelKind::DenseFp8, &i);
        assert_eq!(fp8c.bytes, 3.0 * n * n + 2.0 * n * n * 5.0);
        assert!(fp8c.bytes < f32c.bytes);
        // F16 runs the same fused decode-into-pack branch at runtime, so
        // it pays no separate pack pass either.
        let f16c = kernel_cost(&d, KernelKind::DenseF16, &i);
        assert_eq!(f16c.bytes, 3.0 * n * n * 2.0);
    }

    #[test]
    fn auto_moves_fewer_bytes_than_materializing() {
        let d = DeviceProfile::rtx4090();
        let auto = kernel_cost(&d, KernelKind::LowRankAuto, &inp(20480, 512, true));
        let mat = kernel_cost(&d, KernelKind::LowRankFp8, &inp(20480, 512, true));
        assert!(auto.bytes < mat.bytes / 5.0, "auto {} mat {}", auto.bytes, mat.bytes);
    }

    #[test]
    fn parallel_speedup_scales_and_gates() {
        let plan = ShardPlan::default();
        // Large request: meaningful speedup, below the worker count.
        let s = parallel_speedup(KernelKind::DenseF32, &inp(4096, 0, true), &plan);
        assert!(s > 2.0 && s <= plan.workers as f64, "speedup {s}");
        // Below the size gate: no speedup modeled.
        let s = parallel_speedup(KernelKind::DenseF32, &inp(128, 0, true), &plan);
        assert_eq!(s, 1.0);
        // The factor chain has a larger sequential fraction than dense.
        let d = parallel_speedup(KernelKind::DenseF32, &inp(4096, 128, false), &plan);
        let l = parallel_speedup(KernelKind::LowRankFp8, &inp(4096, 128, false), &plan);
        assert!(l < d, "lowrank {l} vs dense {d}");
        // Cold factorization parallelizes worse than a warm chain.
        let warm = parallel_speedup(KernelKind::LowRankFp8, &inp(4096, 128, true), &plan);
        assert!(warm > l);
    }

    #[test]
    fn rectangular_shapes_supported() {
        let d = DeviceProfile::rtx4090();
        let c = kernel_cost(
            &d,
            KernelKind::DenseF32,
            &SelectorInputs {
                m: 128,
                k: 4096,
                n: 16,
                error_tolerance: 1.0,
                rank: 8,
                factors_cached: true,
                factored_output_ok: false,
                decomp_amortization: 1.0,
                fp8_reencode: false,
            },
        );
        assert!(c.time_s > 0.0);
        assert!((c.flops - 2.0 * 128.0 * 4096.0 * 16.0).abs() < 1.0);
    }
}
