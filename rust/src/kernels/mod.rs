//! Kernel registry, cost model and the paper's `AutoKernelSelector`.
//!
//! The selector is the "intelligent kernel selection" of §3.3.2/Listing 1:
//! given a GEMM request (shapes, error tolerance, precision preference,
//! whether factors are already cached) it scores every applicable kernel
//! with the analytic cost model and picks the cheapest one that satisfies
//! the accuracy constraint.

pub mod cost;
pub mod selector;

pub use cost::{kernel_cost, parallel_speedup, CostEstimate};
pub use selector::{AutoKernelSelector, KernelChoice, KernelKind, SelectorInputs};
