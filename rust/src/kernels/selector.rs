//! The AutoKernelSelector (paper Listing 1 / §3.3.2).

use std::sync::Arc;

use crate::accuracy::ErrorModel;
use crate::autotune::CalibrationTable;
use crate::fp8::{Fp8Format, StorageFormat};
use crate::gpu_sim::profile::{DeviceProfile, Precision};
use crate::kernels::cost::{kernel_cost, parallel_speedup, CostEstimate};
use crate::lowrank::errors::predicted_rel_error;
use crate::shard::ShardPlan;

/// The kernels the router can dispatch to — the paper's §4.4 method list.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Dense GEMM, f32 storage + compute ("PyTorch FP32").
    DenseF32,
    /// Dense GEMM, f16 storage, f32 accumulate ("TorchCompile FP16").
    DenseF16,
    /// Dense GEMM, fp8 storage, f16 compute / f32 accumulate ("cuBLAS FP8").
    DenseFp8,
    /// Factor-chain GEMM with FP8-stored factors ("LowRank FP8").
    LowRankFp8,
    /// Factor-chain GEMM, factored output accepted ("LowRank Auto" fastest
    /// path).
    LowRankAuto,
}

impl KernelKind {
    /// All kernels, in the paper's Table-1 row order.
    pub const ALL: [KernelKind; 5] = [
        KernelKind::DenseF32,
        KernelKind::DenseF16,
        KernelKind::DenseFp8,
        KernelKind::LowRankFp8,
        KernelKind::LowRankAuto,
    ];

    /// Paper's display name.
    pub fn paper_name(self) -> &'static str {
        match self {
            KernelKind::DenseF32 => "PyTorch FP32",
            KernelKind::DenseF16 => "TorchCompile FP16",
            KernelKind::DenseFp8 => "cuBLAS Optimized FP8",
            KernelKind::LowRankFp8 => "LowRank FP8",
            KernelKind::LowRankAuto => "LowRank Auto",
        }
    }

    /// Short id for configs/CLI.
    pub fn id(self) -> &'static str {
        match self {
            KernelKind::DenseF32 => "dense_f32",
            KernelKind::DenseF16 => "dense_f16",
            KernelKind::DenseFp8 => "dense_fp8",
            KernelKind::LowRankFp8 => "lowrank_fp8",
            KernelKind::LowRankAuto => "lowrank_auto",
        }
    }

    /// Parse a short id.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "dense_f32" => KernelKind::DenseF32,
            "dense_f16" => KernelKind::DenseF16,
            "dense_fp8" => KernelKind::DenseFp8,
            "lowrank_fp8" => KernelKind::LowRankFp8,
            "lowrank_auto" | "auto" => KernelKind::LowRankAuto,
            _ => return None,
        })
    }

    /// Is this a factor-chain kernel?
    pub fn is_lowrank(self) -> bool {
        matches!(self, KernelKind::LowRankFp8 | KernelKind::LowRankAuto)
    }

    /// Storage precision the kernel uses for its operands.
    pub fn storage(self) -> StorageFormat {
        match self {
            KernelKind::DenseF32 => StorageFormat::F32,
            KernelKind::DenseF16 => StorageFormat::F16,
            KernelKind::DenseFp8 | KernelKind::LowRankFp8 | KernelKind::LowRankAuto => {
                StorageFormat::Fp8(Fp8Format::E4M3)
            }
        }
    }

    /// Compute (math) precision for the roofline model. FP8 kernels do
    /// their arithmetic in f16 — "FP8 storage, FP16 compute, FP32
    /// accumulate" (§3.3); storage width comes from [`KernelKind::storage`].
    pub fn compute_precision(self) -> Precision {
        match self {
            KernelKind::DenseF32 => Precision::F32,
            _ => Precision::F16,
        }
    }
}

/// Everything the selector needs to know about one request.
#[derive(Clone, Copy, Debug)]
pub struct SelectorInputs {
    /// GEMM shape (m, k, n).
    pub m: usize,
    /// Inner dimension.
    pub k: usize,
    /// Output columns.
    pub n: usize,
    /// Relative-error tolerance the caller accepts (1.0 = anything).
    pub error_tolerance: f32,
    /// Rank the low-rank path would use (from the rank strategy).
    pub rank: usize,
    /// Are both operands' factors already cached (offline decomposition)?
    pub factors_cached: bool,
    /// Will the consumer accept a factored (non-materialized) result?
    pub factored_output_ok: bool,
    /// Amortized-decomposition term (factor-cache plane): the expected
    /// number of requests a cold decomposition's factors will serve. The
    /// cost model divides the factorization charge by it, so a cacheable
    /// miss is priced at its amortized cost instead of the full cold
    /// cost. 1.0 (the default everywhere the cache plane is off) charges
    /// the full cold cost and is bit-identical to the pre-cache model.
    pub decomp_amortization: f64,
    /// Will this request's factors round-trip through the content cache's
    /// FP8 storage (`[cache].fp8`)? That path re-encodes cached factors
    /// through the FP8 codec, an error source the analytic model used to
    /// leave uncharged; when set, low-rank kernels pay one extra FP8
    /// quantization term. `false` (the default everywhere the cache plane
    /// is off or storing f32) is bit-identical to the uncharged model.
    pub fp8_reencode: bool,
}

/// The selector's verdict for one request.
#[derive(Clone, Copy, Debug)]
pub struct KernelChoice {
    /// Which kernel to run.
    pub kind: KernelKind,
    /// Predicted cost on the device. When a calibration table is bound,
    /// `cost.time_s` already includes the measured correction factor.
    pub cost: CostEstimate,
    /// Predicted relative error of the chosen kernel. When an error model
    /// is bound (the accuracy plane), this already includes the probed
    /// correction factor.
    pub predicted_error: f32,
    /// The autotune correction folded into `cost.time_s` (1.0 when no
    /// calibration table is bound or the cell is unsampled). Dividing it
    /// back out recovers the raw analytic prediction — the baseline the
    /// coordinator records observed/predicted ratios against.
    pub calibration: f64,
    /// The accuracy-plane correction folded into `predicted_error` (1.0
    /// when no error model is bound or the cell is unprobed). Dividing it
    /// back out recovers the raw analytic error prediction — the baseline
    /// the accuracy plane records probed/predicted ratios against.
    pub error_correction: f64,
}

/// Hardware-aware kernel selection (paper Listing 1's `AutoKernelSelector`).
#[derive(Clone, Debug)]
pub struct AutoKernelSelector {
    /// Device the selector optimizes for.
    pub device: DeviceProfile,
    /// Shard plan of the tile-execution plane, when one is serving; its
    /// modeled speedup keeps the selector calibrated against the actual
    /// (parallel) execution substrate.
    pub shard: Option<ShardPlan>,
    /// Online calibration table (the autotune plane): measured
    /// per-(kernel, size-class) corrections blended over the analytic
    /// model. `None` (the default) keeps the selector purely analytic.
    pub calibration: Option<Arc<CalibrationTable>>,
    /// Calibrated error model (the accuracy plane): probed
    /// per-(kernel, size-class, rank-class) corrections blended over the
    /// analytic error prediction, so the tolerance gate routes on
    /// observed rather than assumed accuracy. `None` (the default) keeps
    /// error prediction purely analytic.
    pub error_model: Option<Arc<ErrorModel>>,
}

impl AutoKernelSelector {
    /// Bind to a device (single-threaded cost model).
    pub fn new(device: DeviceProfile) -> Self {
        AutoKernelSelector {
            device,
            shard: None,
            calibration: None,
            error_model: None,
        }
    }

    /// Bind to a device plus the serving shard plan.
    pub fn with_shard(device: DeviceProfile, plan: ShardPlan) -> Self {
        AutoKernelSelector {
            device,
            shard: Some(plan),
            calibration: None,
            error_model: None,
        }
    }

    /// Attach an online calibration table (builder-style).
    pub fn with_calibration(mut self, table: Arc<CalibrationTable>) -> Self {
        self.calibration = Some(table);
        self
    }

    /// Attach a calibrated error model (builder-style).
    pub fn with_error_model(mut self, model: Arc<ErrorModel>) -> Self {
        self.error_model = Some(model);
        self
    }

    /// Cost + error verdict for one kernel on one request, including the
    /// shard plane's parallel-speedup term when a plan is bound and the
    /// calibration table's measured correction when autotuning is on.
    pub fn estimate(&self, kind: KernelKind, inp: &SelectorInputs) -> KernelChoice {
        let mut cost = kernel_cost(&self.device, kind, inp);
        if let Some(plan) = &self.shard {
            cost.time_s /= parallel_speedup(kind, inp, plan);
        }
        let calibration = match &self.calibration {
            Some(table) => {
                let c = table.correction(kind, inp.m, inp.k, inp.n);
                cost.time_s *= c;
                c
            }
            None => 1.0,
        };
        let mut predicted_error = self.predicted_error(kind, inp);
        let error_correction = match &self.error_model {
            Some(model) => model.correction(kind, inp.m, inp.k, inp.n, inp.rank),
            None => 1.0,
        };
        if error_correction != 1.0 {
            // Applied only when a probed cell actually moved the factor:
            // an unprobed model (correction exactly 1.0) must leave the
            // analytic prediction bit-identical, and the raw prediction
            // can legitimately sit a hair above 1.0 (RMS of clamped
            // truncation + quantization terms), which the clamp here
            // would otherwise disturb.
            predicted_error = ((predicted_error as f64) * error_correction).clamp(0.0, 1.0) as f32;
        }
        KernelChoice {
            kind,
            cost,
            predicted_error,
            calibration,
            error_correction,
        }
    }

    /// Predicted relative error of a kernel on this request. Dense kernels
    /// pay only quantization error; low-rank kernels pay the §5.4.4
    /// heuristic truncation error plus storage quantization.
    pub fn predicted_error(&self, kind: KernelKind, inp: &SelectorInputs) -> f32 {
        let quant = match kind {
            KernelKind::DenseF32 => 1e-6,
            KernelKind::DenseF16 => 5e-4,
            KernelKind::DenseFp8 => 2e-2,
            KernelKind::LowRankFp8 | KernelKind::LowRankAuto => 2e-2,
        };
        if kind.is_lowrank() {
            let n = inp.k.max(inp.m).max(inp.n);
            let mut sq = quant * quant + {
                let e = predicted_rel_error(n, inp.rank.max(1));
                e * e
            };
            if inp.fp8_reencode {
                // Factors round-tripping through the content cache's FP8
                // storage pay one extra quantization on every hit — an
                // error source the model used to leave uncharged.
                const REENCODE: f32 = 2e-2;
                sq += REENCODE * REENCODE;
            }
            sq.sqrt()
        } else {
            quant
        }
    }

    /// Score all applicable kernels, cheapest-first.
    pub fn ranked(&self, inp: &SelectorInputs) -> Vec<KernelChoice> {
        let mut out: Vec<KernelChoice> = KernelKind::ALL
            .iter()
            .filter(|k| {
                // LowRankAuto's factored-output trick needs caller opt-in.
                **k != KernelKind::LowRankAuto || inp.factored_output_ok
            })
            .map(|&kind| self.estimate(kind, inp))
            .collect();
        // total_cmp: a NaN cost (e.g. a degenerate calibration ratio)
        // sorts last instead of panicking the serving path.
        out.sort_by(|a, b| a.cost.time_s.total_cmp(&b.cost.time_s));
        out
    }

    /// Pick the fastest kernel whose predicted error fits the tolerance;
    /// fall back to the most accurate one if nothing fits.
    pub fn select(&self, inp: &SelectorInputs) -> KernelChoice {
        Self::select_from(&self.ranked(inp), inp)
    }

    /// [`select`](AutoKernelSelector::select) over an already-[`ranked`]
    /// list — callers that need both the list and the winner (e.g. the
    /// router's exploration path) avoid scoring every kernel twice.
    ///
    /// [`ranked`]: AutoKernelSelector::ranked
    pub fn select_from(ranked: &[KernelChoice], inp: &SelectorInputs) -> KernelChoice {
        ranked
            .iter()
            .find(|c| c.predicted_error <= inp.error_tolerance)
            .copied()
            .unwrap_or_else(|| {
                *ranked
                    .iter()
                    .min_by(|a, b| a.predicted_error.total_cmp(&b.predicted_error))
                    .expect("at least one kernel")
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs(n: usize, rank: usize) -> SelectorInputs {
        SelectorInputs {
            m: n,
            k: n,
            n,
            error_tolerance: 0.05,
            rank,
            factors_cached: true,
            factored_output_ok: true,
            decomp_amortization: 1.0,
            fp8_reencode: false,
        }
    }

    fn sel() -> AutoKernelSelector {
        AutoKernelSelector::new(DeviceProfile::rtx4090())
    }

    #[test]
    fn small_matrices_pick_dense() {
        // Paper §5.1: dense wins for N ≤ 4096.
        let s = sel();
        let choice = s.select(&inputs(1024, 64));
        assert!(!choice.kind.is_lowrank(), "chose {:?}", choice.kind);
    }

    #[test]
    fn large_matrices_pick_lowrank() {
        // Paper §5.1: LowRank Auto fastest for N ≥ 10240 (r = N/40).
        let s = sel();
        let choice = s.select(&inputs(20480, 512));
        assert_eq!(choice.kind, KernelKind::LowRankAuto);
    }

    #[test]
    fn tight_tolerance_forces_exact() {
        let s = sel();
        let mut inp = inputs(20480, 512);
        inp.error_tolerance = 1e-5;
        let choice = s.select(&inp);
        assert_eq!(choice.kind, KernelKind::DenseF32);
    }

    #[test]
    fn factored_output_gate_respected() {
        let s = sel();
        let mut inp = inputs(20480, 512);
        inp.factored_output_ok = false;
        let ranked = s.ranked(&inp);
        assert!(ranked.iter().all(|c| c.kind != KernelKind::LowRankAuto));
    }

    #[test]
    fn cold_factors_penalize_lowrank() {
        let s = sel();
        let mut inp = inputs(8192, 256);
        inp.factors_cached = false;
        let cold = s
            .ranked(&inp)
            .into_iter()
            .find(|c| c.kind == KernelKind::LowRankFp8)
            .unwrap();
        inp.factors_cached = true;
        let warm = s
            .ranked(&inp)
            .into_iter()
            .find(|c| c.kind == KernelKind::LowRankFp8)
            .unwrap();
        assert!(cold.cost.time_s > warm.cost.time_s * 1.5);
    }

    #[test]
    fn crossover_in_paper_band() {
        // Find the N where LowRankAuto first beats all dense kernels
        // (rank = N/40 as in the paper's r=512 @ N=20480 operating point).
        // Cold factors + materialized output: the paper's Table-1 regime
        // (its harness re-decomposes inside the timed region — the 0.5
        // TFLOPS row at N=1024 is decomposition overhead).
        let s = sel();
        let mut crossover = None;
        for exp in 0..14 {
            let n = (1024.0 * (2.0f64).powf(exp as f64 / 2.0)).round() as usize;
            let mut inp = inputs(n, (n / 40).max(16));
            inp.factors_cached = false;
            let c = s.select(&inp);
            if c.kind.is_lowrank() {
                crossover = Some(n);
                break;
            }
        }
        let x = crossover.expect("lowrank should win eventually");
        // Paper says ~10240; accept a generous band around it.
        assert!((4096..=20480).contains(&x), "crossover at {x}");
    }

    #[test]
    fn amortization_flips_the_crossover_earlier() {
        // The factor-cache plane's routing claim: amortizing a cold
        // decomposition over its expected reuses moves the low-rank
        // crossover to smaller N than the paper's cold regime.
        let s = sel();
        let crossover_at = |amort: f64| {
            for exp in 0..14 {
                let n = (1024.0 * (2.0f64).powf(exp as f64 / 2.0)).round() as usize;
                let mut inp = inputs(n, (n / 40).max(16));
                inp.factors_cached = false;
                inp.decomp_amortization = amort;
                if s.select(&inp).kind.is_lowrank() {
                    return n;
                }
            }
            usize::MAX
        };
        let cold = crossover_at(1.0);
        let amortized = crossover_at(16.0);
        // Amortization only ever cheapens low-rank kernels, so the
        // crossover can't move later…
        assert!(
            amortized <= cold,
            "amortized crossover {amortized} must not exceed cold {cold}"
        );
        // …and at 16 expected reuses it sits near the warm regime, well
        // below the paper's cold N ≥ 10240 operating point.
        assert!(
            amortized <= 4096,
            "amortized crossover {amortized} should be warm-adjacent"
        );
    }

    #[test]
    fn ranked_is_sorted() {
        let s = sel();
        let ranked = s.ranked(&inputs(4096, 128));
        for w in ranked.windows(2) {
            assert!(w[0].cost.time_s <= w[1].cost.time_s);
        }
    }

    #[test]
    fn shard_plan_discounts_large_requests_only() {
        let plain = sel();
        let sharded = AutoKernelSelector::with_shard(
            DeviceProfile::rtx4090(),
            crate::shard::ShardPlan::default(),
        );
        let big = inputs(8192, 256);
        let a = plain.estimate(KernelKind::DenseF32, &big);
        let b = sharded.estimate(KernelKind::DenseF32, &big);
        assert!(b.cost.time_s < a.cost.time_s);
        // Below the gate the two selectors agree exactly.
        let small = inputs(128, 8);
        let a = plain.estimate(KernelKind::DenseF32, &small);
        let b = sharded.estimate(KernelKind::DenseF32, &small);
        assert_eq!(a.cost.time_s, b.cost.time_s);
    }

    #[test]
    fn id_parse_roundtrip() {
        for k in KernelKind::ALL {
            assert_eq!(KernelKind::parse(k.id()), Some(k));
        }
        assert_eq!(KernelKind::parse("magic"), None);
    }

    #[test]
    fn impossible_tolerance_falls_back_to_most_accurate() {
        let s = sel();
        let mut inp = inputs(2048, 64);
        inp.error_tolerance = 0.0;
        let c = s.select(&inp);
        assert_eq!(c.kind, KernelKind::DenseF32);
    }

    #[test]
    fn empty_calibration_table_is_bit_identical() {
        // Acceptance gate: autotune bound but unsampled must not perturb
        // a single bit of the static model's output.
        let plain = sel();
        let table = std::sync::Arc::new(CalibrationTable::new(0.2, 5));
        let tuned = sel().with_calibration(table);
        for n in [256, 1024, 4096, 20480] {
            let inp = inputs(n, (n / 40).max(16));
            for (a, b) in plain.ranked(&inp).iter().zip(tuned.ranked(&inp)) {
                assert_eq!(a.kind, b.kind);
                assert_eq!(a.cost.time_s.to_bits(), b.cost.time_s.to_bits());
                assert_eq!(b.calibration, 1.0);
            }
        }
    }

    #[test]
    fn calibration_skew_reprices_one_kernel() {
        let table = std::sync::Arc::new(CalibrationTable::new(0.5, 0));
        let s = sel().with_calibration(table.clone());
        let inp = inputs(4096, 128);
        let before = s.estimate(KernelKind::DenseF16, &inp);
        assert_eq!(before.calibration, 1.0);
        // Observed 8x slower than predicted; prior strength 0 trusts the
        // measurement immediately.
        let raw = before.cost.time_s;
        table.record(KernelKind::DenseF16, 4096, 4096, 4096, raw, raw * 8.0);
        let after = s.estimate(KernelKind::DenseF16, &inp);
        assert!((after.calibration - 8.0).abs() < 1e-9, "{}", after.calibration);
        assert!((after.cost.time_s - raw * 8.0).abs() < raw * 1e-9);
        // Other kernels and size classes stay analytic.
        assert_eq!(s.estimate(KernelKind::DenseF32, &inp).calibration, 1.0);
        let other = inputs(1024, 64);
        assert_eq!(s.estimate(KernelKind::DenseF16, &other).calibration, 1.0);
    }

    #[test]
    fn empty_error_model_is_bit_identical() {
        // Acceptance gate: accuracy plane bound but unprobed must not
        // perturb a single bit of the analytic error prediction.
        let plain = sel();
        let model = std::sync::Arc::new(ErrorModel::new(0.2, 5));
        let probed = sel().with_error_model(model);
        for n in [256, 1024, 4096, 20480] {
            let inp = inputs(n, (n / 40).max(16));
            for (a, b) in plain.ranked(&inp).iter().zip(probed.ranked(&inp)) {
                assert_eq!(a.kind, b.kind);
                assert_eq!(
                    a.predicted_error.to_bits(),
                    b.predicted_error.to_bits(),
                    "{:?} @ n={n}",
                    a.kind
                );
                assert_eq!(b.error_correction, 1.0);
            }
        }
    }

    #[test]
    fn probed_error_skew_flips_the_tolerance_gate() {
        // The plane's routing claim: a kernel whose *probed* error blows
        // its predicted error must lose requests it used to win on faith.
        let model = std::sync::Arc::new(ErrorModel::new(0.5, 0));
        let s = sel().with_error_model(model.clone());
        let inp = inputs(20480, 512);
        let before = s.select(&inp);
        assert!(before.kind.is_lowrank());
        let raw = before.predicted_error as f64 / before.error_correction;
        // Probes observe 5x the predicted error — enough to blow the 5%
        // tolerance; prior strength 0 trusts the probes immediately.
        for kind in [KernelKind::LowRankAuto, KernelKind::LowRankFp8] {
            model.record(kind, 20480, 20480, 20480, 512, raw, raw * 5.0);
        }
        let after = s.select(&inp);
        assert!(
            !after.kind.is_lowrank(),
            "calibrated error must force a dense kernel, got {:?}",
            after.kind
        );
        // The repriced low-rank candidates carry the blown prediction.
        let lr = s
            .ranked(&inp)
            .into_iter()
            .find(|c| c.kind == KernelKind::LowRankAuto)
            .unwrap();
        assert!((lr.error_correction - 5.0).abs() < 1e-9);
        assert!(lr.predicted_error > inp.error_tolerance);
        // Unprobed cells (other kernels / shapes) stay analytic.
        assert_eq!(s.estimate(KernelKind::DenseF16, &inp).error_correction, 1.0);
        let other = inputs(1024, 64);
        assert_eq!(
            s.estimate(KernelKind::LowRankAuto, &other).error_correction,
            1.0
        );
    }

    #[test]
    fn fp8_reencode_charges_lowrank_error_only() {
        let s = sel();
        let plain = inputs(8192, 256);
        let mut reenc = plain;
        reenc.fp8_reencode = true;
        for kind in KernelKind::ALL {
            let a = s.estimate(kind, &plain);
            let b = s.estimate(kind, &reenc);
            if kind.is_lowrank() {
                assert!(
                    b.predicted_error > a.predicted_error,
                    "{kind:?} must pay the re-encode term"
                );
            } else {
                assert_eq!(
                    a.predicted_error.to_bits(),
                    b.predicted_error.to_bits(),
                    "{kind:?} has no cached factors to re-encode"
                );
            }
            // The charge is an error term, never a time term.
            assert_eq!(a.cost.time_s.to_bits(), b.cost.time_s.to_bits());
        }
    }

    #[test]
    fn nan_cost_cannot_panic_ranked_or_select() {
        // A hostile table entry cannot produce NaN (record clamps), but
        // the serving path must survive one anyway: total_cmp sorts NaN
        // last instead of panicking.
        let s = sel();
        let mut ranked = s.ranked(&inputs(1024, 64));
        ranked[0].cost.time_s = f64::NAN;
        ranked.sort_by(|a, b| a.cost.time_s.total_cmp(&b.cost.time_s));
        assert!(ranked.last().unwrap().cost.time_s.is_nan());
    }
}
