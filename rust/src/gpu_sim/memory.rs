//! Simulated device-memory tracker.
//!
//! Backs the Table-2 "Memory Used / Memory %" columns and the
//! hardware-aware rank strategy: a simple high-water-mark allocator model
//! with named allocations, so benchmark reports can show *what* is
//! resident (matrices, factors, workspace) at peak.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Tracks simulated allocations against a device capacity.
#[derive(Debug)]
pub struct MemoryTracker {
    capacity: u64,
    live: HashMap<String, u64>,
    current: u64,
    peak: u64,
    peak_breakdown: Vec<(String, u64)>,
}

impl MemoryTracker {
    /// New tracker for a device with `capacity` bytes.
    pub fn new(capacity: u64) -> Self {
        MemoryTracker {
            capacity,
            live: HashMap::new(),
            current: 0,
            peak: 0,
            peak_breakdown: Vec::new(),
        }
    }

    /// Allocate `bytes` under `name`; errors if the device would OOM.
    pub fn alloc(&mut self, name: &str, bytes: u64) -> Result<()> {
        if self.current + bytes > self.capacity {
            return Err(Error::Service(format!(
                "simulated OOM: {} + {} > capacity {} (allocating '{}')",
                self.current, bytes, self.capacity, name
            )));
        }
        *self.live.entry(name.to_string()).or_insert(0) += bytes;
        self.current += bytes;
        if self.current > self.peak {
            self.peak = self.current;
            self.peak_breakdown = self
                .live
                .iter()
                .map(|(k, &v)| (k.clone(), v))
                .collect();
            self.peak_breakdown.sort_by(|a, b| b.1.cmp(&a.1));
        }
        Ok(())
    }

    /// Free everything allocated under `name`.
    pub fn free(&mut self, name: &str) {
        if let Some(bytes) = self.live.remove(name) {
            self.current -= bytes;
        }
    }

    /// Currently resident bytes.
    pub fn current(&self) -> u64 {
        self.current
    }

    /// High-water mark.
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Peak as a fraction of capacity (Table 2's "Memory %").
    pub fn peak_fraction(&self) -> f64 {
        self.peak as f64 / self.capacity as f64
    }

    /// What was resident at the high-water mark, largest first.
    pub fn peak_breakdown(&self) -> &[(String, u64)] {
        &self.peak_breakdown
    }

    /// Would an allocation of `bytes` fit right now?
    pub fn would_fit(&self, bytes: u64) -> bool {
        self.current + bytes <= self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut t = MemoryTracker::new(1000);
        t.alloc("a", 300).unwrap();
        t.alloc("b", 400).unwrap();
        assert_eq!(t.current(), 700);
        t.free("a");
        assert_eq!(t.current(), 400);
        assert_eq!(t.peak(), 700);
    }

    #[test]
    fn oom_detected() {
        let mut t = MemoryTracker::new(100);
        t.alloc("a", 90).unwrap();
        assert!(t.alloc("b", 20).is_err());
        assert_eq!(t.current(), 90);
    }

    #[test]
    fn peak_breakdown_sorted() {
        let mut t = MemoryTracker::new(1000);
        t.alloc("small", 100).unwrap();
        t.alloc("big", 500).unwrap();
        t.free("small");
        t.free("big");
        let bd = t.peak_breakdown();
        assert_eq!(bd[0].0, "big");
        assert_eq!(bd[1].0, "small");
        assert_eq!(t.peak(), 600);
    }

    #[test]
    fn named_accumulation() {
        let mut t = MemoryTracker::new(1000);
        t.alloc("ws", 100).unwrap();
        t.alloc("ws", 150).unwrap();
        assert_eq!(t.current(), 250);
        t.free("ws");
        assert_eq!(t.current(), 0);
    }

    #[test]
    fn would_fit() {
        let mut t = MemoryTracker::new(100);
        assert!(t.would_fit(100));
        t.alloc("x", 60).unwrap();
        assert!(t.would_fit(40));
        assert!(!t.would_fit(41));
    }

    #[test]
    fn peak_fraction_table2_style() {
        // 3.75 GB of 25.2 GB ≈ 15% (paper Table 2, LowRank rows).
        let mut t = MemoryTracker::new(25_200_000_000);
        t.alloc("factors", 3_750_000_000).unwrap();
        assert!((t.peak_fraction() - 0.1488).abs() < 0.001);
    }
}
