//! Roofline GPU simulator — the reproduction's stand-in for the paper's
//! RTX 4090 testbed (and the H200/B200 extrapolation targets of Table 3).
//!
//! The paper's §6.2 performance argument is entirely a roofline argument:
//! time-per-op = max(FLOPs / peak, bytes / bandwidth) + launch overhead.
//! This module implements that model *explicitly*, parameterized by the
//! spec-sheet constants the paper itself quotes, so every Table-1/2/3 and
//! Figure-1 number can be regenerated — and audited — from first
//! principles. Numerics always run on the real CPU substrate; only *time*
//! is simulated.

pub mod memory;
pub mod profile;
pub mod roofline;

pub use memory::MemoryTracker;
pub use profile::{DeviceProfile, Precision};
pub use roofline::{OpCost, Roofline, SimResult};
