//! Device profiles: the spec-sheet constants the roofline model consumes.
//!
//! Numbers mirror the paper's §4.1 / §6.2 / §6.3 exactly where the paper
//! states them (RTX 4090: 1 TB/s, 1.321 PFLOP/s FP8, 25.2 GB(*); H200:
//! 4.8 TB/s, 4 PFLOP/s, 141 GB; B200: 8 TB/s, 20 PFLOP/s, 192 GB), so the
//! reproduction's Table 3 is generated from the same inputs.
//!
//! (*) the 4090 actually has 24 GB; 25.2 GB is what the paper prints — we
//! keep the paper's value and note the discrepancy in EXPERIMENTS.md.

/// Compute precision for peak-FLOPS lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    /// 32-bit CUDA-core / scalar path.
    F32,
    /// 16-bit TensorCore/MXU path.
    F16,
    /// 8-bit TensorCore path.
    Fp8,
}

impl Precision {
    /// Storage bytes per element at this precision.
    pub fn bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::F16 => 2,
            Precision::Fp8 => 1,
        }
    }
}

/// A device the roofline model can simulate.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    /// Human name used in reports.
    pub name: &'static str,
    /// HBM/GDDR capacity in bytes.
    pub memory_bytes: u64,
    /// Sustained memory bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Peak FLOP/s at F32.
    pub peak_f32: f64,
    /// Peak FLOP/s at F16 (TensorCore).
    pub peak_f16: f64,
    /// Peak FLOP/s at FP8 (TensorCore).
    pub peak_fp8: f64,
    /// Fixed per-kernel launch + sync overhead, seconds.
    pub launch_overhead_s: f64,
    /// Fraction of nominal bandwidth achievable by a tuned kernel
    /// (the paper's §6.2 grants 60–80% to cuBLAS-class kernels; we use
    /// the midpoint and sweep it in the ablation bench).
    pub bandwidth_efficiency: f64,
    /// Fraction of peak FLOPs achievable by a tuned dense kernel.
    pub compute_efficiency: f64,
}

impl DeviceProfile {
    /// Peak FLOP/s for a precision.
    pub fn peak_flops(&self, p: Precision) -> f64 {
        match p {
            Precision::F32 => self.peak_f32,
            Precision::F16 => self.peak_f16,
            Precision::Fp8 => self.peak_fp8,
        }
    }

    /// NVIDIA RTX 4090 per the paper (§4.1, §6.2).
    ///
    /// Calibration note (EXPERIMENTS.md §Model-Calibration): `peak_f16` is
    /// the *dense* (non-sparsity) TensorCore rate — the paper's measured
    /// 139 TFLOPS at N=20480 is 84% of it, which is the efficiency band
    /// cuBLAS-class kernels actually reach. `peak_fp8` is the paper's own
    /// §6.2 quoted 1.321 PFLOPS (the 2:4-sparsity marketing number); it is
    /// used only to reproduce the paper's §6.2 percent-of-peak arithmetic,
    /// never as a pipeline compute rate: the paper's "FP8" kernels compute
    /// in FP16 ("FP8 storage, FP16 compute", §3.3.2), and the simulator
    /// does the same.
    pub fn rtx4090() -> Self {
        DeviceProfile {
            name: "rtx4090",
            memory_bytes: 25_200_000_000, // paper's stated 25.2 GB
            bandwidth_bps: 1.0e12,        // §6.2: "approximately 1 TB/s"
            peak_f32: 60.0e12,            // non-TC FP32 with FMA issue limits
            peak_f16: 165.2e12,           // FP16 TensorCore, dense
            peak_fp8: 1.321e15,           // §6.2 step 1 (paper-quoted, sparse)
            launch_overhead_s: 12e-6,     // CUDA launch + sync, typical
            bandwidth_efficiency: 0.70,
            compute_efficiency: 0.85,
        }
    }

    /// NVIDIA H200 per the paper's Table 3 inputs.
    pub fn h200() -> Self {
        DeviceProfile {
            name: "h200",
            memory_bytes: 141_000_000_000,
            bandwidth_bps: 4.8e12,
            peak_f32: 67.0e12,
            peak_f16: 989.0e12,
            peak_fp8: 4.0e15,
            launch_overhead_s: 10e-6,
            bandwidth_efficiency: 0.70,
            compute_efficiency: 0.65,
        }
    }

    /// NVIDIA B200 per the paper's Table 3 inputs.
    pub fn b200() -> Self {
        DeviceProfile {
            name: "b200",
            memory_bytes: 192_000_000_000,
            bandwidth_bps: 8.0e12,
            peak_f32: 80.0e12,
            peak_f16: 2.25e15,
            peak_fp8: 20.0e15,
            launch_overhead_s: 10e-6,
            bandwidth_efficiency: 0.70,
            compute_efficiency: 0.65,
        }
    }

    /// The actual evaluation host (1-core CPU) — used to sanity-check the
    /// simulator against real measured times in the integration tests.
    /// Peak numbers are measured, not spec-sheet: see EXPERIMENTS.md §Perf.
    pub fn cpu_host() -> Self {
        DeviceProfile {
            name: "cpu_host",
            memory_bytes: 8_000_000_000,
            bandwidth_bps: 8.0e9,
            peak_f32: 8.0e9,
            peak_f16: 8.0e9, // no wide SIMD f16: same scalar path
            peak_fp8: 8.0e9,
            launch_overhead_s: 0.0,
            bandwidth_efficiency: 0.8,
            compute_efficiency: 0.6,
        }
    }

    /// Look a profile up by name (CLI / config).
    pub fn by_name(name: &str) -> Option<DeviceProfile> {
        Some(match name {
            "rtx4090" | "4090" => DeviceProfile::rtx4090(),
            "h200" => DeviceProfile::h200(),
            "b200" => DeviceProfile::b200(),
            "cpu" | "cpu_host" => DeviceProfile::cpu_host(),
            _ => return None,
        })
    }

    /// The paper's §6.2 "bandwidth-limited GEMM ceiling" formula, taken
    /// literally: `BW [bytes/s] / bytes-per-element × 2/3 [FLOP/element]`.
    ///
    /// **Audit note** (EXPERIMENTS.md §P1): for the RTX 4090 at FP8 this
    /// evaluates to 6.67e11 FLOP/s = 667 *G*FLOPS, which the paper then
    /// labels "667 TFLOPS" — a 1000× unit slip. The physically correct
    /// bandwidth bound for an N×N GEMM moving 3N² bytes for 2N³ FLOPs is
    /// `(2N/3)·BW`, which at N = 20480 exceeds the compute peak (large
    /// dense GEMM is compute-bound, not bandwidth-bound). We reproduce
    /// the paper's formula here and its *stated* ceiling via
    /// [`DeviceProfile::paper_stated_bw_ceiling_flops`], and document the
    /// discrepancy where §6.2 is regenerated.
    pub fn bandwidth_limited_gemm_flops(&self, p: Precision) -> f64 {
        self.bandwidth_bps / p.bytes() as f64 * (2.0 / 3.0)
    }

    /// The §6.2 ceiling as the paper *states* it ("667 TFLOPS" on the
    /// 4090): the literal formula times the paper's implicit 1000× unit
    /// slip. Kept separate so Table-3 / §6.2 reproductions can print the
    /// paper's numbers while the audit note above stays honest.
    pub fn paper_stated_bw_ceiling_flops(&self, p: Precision) -> f64 {
        self.bandwidth_limited_gemm_flops(p) * 1e3
    }

    /// The physically correct bandwidth-limited FLOP/s for an N×N GEMM
    /// (3N² bytes moved, 2N³ FLOPs): `(2N/3) · BW / bytes-per-element`.
    pub fn physical_bw_limited_gemm_flops(&self, n: usize, p: Precision) -> f64 {
        (2.0 * n as f64 / 3.0) * self.bandwidth_bps / p.bytes() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constants_wired_through() {
        let d = DeviceProfile::rtx4090();
        assert_eq!(d.bandwidth_bps, 1.0e12);
        assert_eq!(d.peak_fp8, 1.321e15);
        // §6.2 step 4's formula, literally: 6.67e11 FLOP/s (667 GFLOPS —
        // the paper calls this "667 TFLOPS"; see the audit note on
        // `bandwidth_limited_gemm_flops`).
        let literal = d.bandwidth_limited_gemm_flops(Precision::Fp8);
        assert!((literal - 666.7e9).abs() / 666.7e9 < 0.001, "{literal:e}");
        // The paper's *stated* ceiling, reproduced for §6.2/Table-3 output.
        let stated = d.paper_stated_bw_ceiling_flops(Precision::Fp8);
        assert!((stated - 666.7e12).abs() / 666.7e12 < 0.001, "{stated:e}");
        // And the physical bound at N=20480 sits above the compute peak:
        // large dense GEMM on this card is compute-bound.
        assert!(d.physical_bw_limited_gemm_flops(20480, Precision::Fp8) > d.peak_fp8);
    }

    #[test]
    fn table3_inputs() {
        let h = DeviceProfile::h200();
        let b = DeviceProfile::b200();
        assert_eq!(h.bandwidth_bps, 4.8e12);
        assert_eq!(b.bandwidth_bps, 8.0e12);
        assert_eq!(h.peak_fp8, 4.0e15);
        assert_eq!(b.peak_fp8, 20.0e15);
    }

    #[test]
    fn by_name_lookup() {
        assert!(DeviceProfile::by_name("rtx4090").is_some());
        assert!(DeviceProfile::by_name("h200").is_some());
        assert!(DeviceProfile::by_name("b200").is_some());
        assert!(DeviceProfile::by_name("tpuv4").is_none());
    }

    #[test]
    fn precision_bytes() {
        assert_eq!(Precision::F32.bytes(), 4);
        assert_eq!(Precision::F16.bytes(), 2);
        assert_eq!(Precision::Fp8.bytes(), 1);
    }
}
