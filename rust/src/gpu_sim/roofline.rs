//! The roofline timing model and the paper's GEMM pipelines expressed in it.
//!
//! Every simulated operation is reduced to `(flops, bytes_moved, launches)`
//! and timed as
//!
//! ```text
//! t = launches · t_launch
//!   + max( flops / (peak·compute_eff), bytes / (BW·bw_eff) )
//! ```
//!
//! which is exactly the §6.2 model with the efficiency factors the paper
//! concedes ("SOTA libraries achieve 60–80% of bandwidth peak"). The five
//! comparison methods of §4.4 are each expressed as a pipeline of such ops.

use crate::gpu_sim::profile::{DeviceProfile, Precision};

/// Cost of one device operation in model units.
#[derive(Clone, Copy, Debug, Default)]
pub struct OpCost {
    /// Floating-point operations.
    pub flops: f64,
    /// Bytes moved through device memory.
    pub bytes: f64,
    /// Kernel launches.
    pub launches: f64,
}

impl OpCost {
    /// Sum two costs (sequential composition).
    pub fn then(self, other: OpCost) -> OpCost {
        OpCost {
            flops: self.flops + other.flops,
            bytes: self.bytes + other.bytes,
            launches: self.launches + other.launches,
        }
    }
}

/// Roofline evaluator bound to a device.
#[derive(Clone, Debug)]
pub struct Roofline {
    /// Device constants.
    pub device: DeviceProfile,
}

impl Roofline {
    /// Bind the model to a device profile.
    pub fn new(device: DeviceProfile) -> Self {
        Roofline { device }
    }

    /// Simulated wall time of an op at a compute precision.
    pub fn time(&self, cost: &OpCost, p: Precision) -> f64 {
        let d = &self.device;
        let compute = cost.flops / (d.peak_flops(p) * d.compute_efficiency);
        let memory = cost.bytes / (d.bandwidth_bps * d.bandwidth_efficiency);
        cost.launches * d.launch_overhead_s + compute.max(memory)
    }

    /// Achieved FLOP/s for a *useful-work* flop count over a simulated time.
    pub fn achieved_flops(useful_flops: f64, time_s: f64) -> f64 {
        if time_s <= 0.0 {
            0.0
        } else {
            useful_flops / time_s
        }
    }

    // ------------------------------------------------------------------
    // The §4.4 comparison pipelines. All operate on square N×N GEMM and
    // report (time, effective TFLOPS of the dense-equivalent 2N³ work,
    // peak resident bytes). `r` is the retained rank for low-rank methods.
    // ------------------------------------------------------------------

    /// Dense GEMM at a storage precision: read A, B; write C; one kernel.
    pub fn dense_gemm_cost(&self, n: usize, p: Precision) -> OpCost {
        let nn = n as f64 * n as f64;
        OpCost {
            flops: 2.0 * nn * n as f64,
            bytes: 3.0 * nn * p.bytes() as f64,
            launches: 1.0,
        }
    }

    /// Method 1 — "PyTorch FP32": dense GEMM, FP32 storage + compute, plus
    /// the framework's extra launch/dispatch overhead.
    pub fn pytorch_f32(&self, n: usize) -> SimResult {
        let cost = self.dense_gemm_cost(n, Precision::F32).then(OpCost {
            launches: 2.0, // dispatcher + allocator traffic
            ..Default::default()
        });
        self.finish(n, cost, Precision::F32, 3.0 * sq(n) * 4.0, 5.0)
    }

    /// Method 3 — "TorchCompile FP16": dense GEMM on TensorCores, F16
    /// storage, fused single kernel.
    pub fn torchcompile_f16(&self, n: usize) -> SimResult {
        let cost = self.dense_gemm_cost(n, Precision::F16);
        self.finish(n, cost, Precision::F16, 3.0 * sq(n) * 2.0, 2.5)
    }

    /// Method 2 — "cuBLAS Optimized FP8": dense GEMM with FP8 *storage*
    /// (1-byte traffic) but **FP16 compute** — §4.4 calls it a "custom FP8
    /// simulation with TensorCore acceleration"; the 4090 exposes no FP8
    /// matmul through torch, so the paper's kernel (like ours) upcasts to
    /// f16 in registers. That is why Table 1 reports it a hair *below*
    /// TorchCompile FP16 (137 vs 139): same math rate, plus quant passes.
    pub fn cublas_fp8(&self, n: usize) -> SimResult {
        let quant = OpCost {
            flops: 2.0 * sq(n),
            bytes: 2.0 * sq(n) * (4.0 + 1.0), // read f32, write fp8, both matrices
            launches: 2.0,
        };
        let cost = quant.then(self.dense_gemm_cost(n, Precision::Fp8));
        self.finish(n, cost, Precision::F16, 3.0 * sq(n) * 2.0, 2.5)
    }

    /// Extra launches charged per factorization for the decomposition
    /// *pipeline* (projection, panel QR, small SVD, transposes, python
    /// dispatch). Calibrated from the paper's own Table 1: LowRank at
    /// N=1024 achieves 0.5 TFLOPS → 2·N³/0.5e12 ≈ 4.3 ms per GEMM, i.e.
    /// ≈ 2.1 ms of fixed overhead per operand factorization; at 12 µs per
    /// launch that is ~180 launches. This single constant reproduces both
    /// the paper's terrible small-N low-rank numbers and its N≈10⁴
    /// crossover (EXPERIMENTS.md §Model-Calibration).
    pub const SVD_PIPELINE_LAUNCHES: f64 = 180.0;

    /// Low-rank factor-chain GEMM cost at rank r with factors already
    /// resident (offline decomposition — the serving steady state).
    pub fn lowrank_apply_cost(&self, n: usize, r: usize, p: Precision) -> OpCost {
        let (nf, rf) = (n as f64, r as f64);
        // T1 = VAᵀ·UB (r×r over k=n), T2 scalings, T3 = T2·VBᵀ (r×n),
        // C = UA·T3 (n×n over r). Bytes: read 4 factors (2·2·n·r), write C.
        OpCost {
            flops: 2.0 * rf * nf * rf + 2.0 * rf * rf + 2.0 * rf * rf * nf + 2.0 * nf * rf * nf,
            bytes: 4.0 * nf * rf * p.bytes() as f64 + sq(n) * p.bytes() as f64,
            launches: 4.0,
        }
    }

    /// Cost of factorizing one N×N matrix at rank r via randomized SVD
    /// with q = 2 power iterations (2q+1 = 5 passes over A), plus the
    /// small QR/SVD tail and the pipeline-launch overhead above. Charged
    /// on cache misses and in the paper's (cold) Table-1 runs.
    pub fn rsvd_cost(&self, n: usize, r: usize, p: Precision) -> OpCost {
        let (nf, rf) = (n as f64, r as f64);
        let l = rf + 8.0;
        OpCost {
            // 5 sketch/power passes + QR + B = Qᵀ·A + small SVD ~ O(n l²).
            flops: 5.0 * (2.0 * sq(n) * l) + 8.0 * nf * l * l,
            // Five streaming passes over A plus factor I/O.
            bytes: 5.0 * sq(n) * p.bytes() as f64 + 4.0 * nf * l * p.bytes() as f64,
            launches: Self::SVD_PIPELINE_LAUNCHES,
        }
    }

    /// Method 4 — "LowRank FP8" as Table 1 measures it: factorization on
    /// the request (the paper's harness re-decomposes inside the timed
    /// region — its N=1024 row reads 0.5 TFLOPS, which is pure
    /// decomposition overhead). SVD-class kernels run in F32; the chain
    /// applies in F16 with fp8-width traffic.
    pub fn lowrank_fp8(&self, n: usize, r: usize) -> SimResult {
        let fact = self.rsvd_cost(n, r, Precision::F32);
        let fact_time = 2.0 * self.time(&fact, Precision::F32);
        let chain = self.lowrank_apply_cost(n, r, Precision::Fp8);
        let chain_time = self.time(&chain, Precision::F16);
        let resident = (2.0 * (2.0 * n as f64 * r as f64) + 2.0 * sq(n)) * 1.0;
        self.finish_timed(n, fact_time + chain_time, fact.then(fact).then(chain), resident, 3.75 / 3.0)
    }

    /// Method 4, warm: factors cached (the serving steady state).
    pub fn lowrank_fp8_warm(&self, n: usize, r: usize) -> SimResult {
        let chain = self.lowrank_apply_cost(n, r, Precision::Fp8);
        let t = self.time(&chain, Precision::F16);
        let resident = (2.0 * (2.0 * n as f64 * r as f64) + sq(n)) * 1.0;
        self.finish_timed(n, t, chain, resident, 3.75 / 3.0)
    }

    /// Backwards-compatible alias for the cold path.
    pub fn lowrank_fp8_cold(&self, n: usize, r: usize) -> SimResult {
        self.lowrank_fp8(n, r)
    }

    /// Method 5 — "LowRank Auto": the auto-selector's fast path. Two
    /// structural advantages over LowRank FP8 (both from the paper's §3.3
    /// description of the auto kernel): the sketch/power passes run on
    /// TensorCores in f16 instead of f32, and the result stays factored
    /// when the consumer accepts it (no dense C materialization), so the
    /// bytes drop to factor traffic — the paper's "memory bandwidth
    /// optimization rather than computational shortcuts".
    pub fn lowrank_auto(&self, n: usize, r: usize) -> SimResult {
        let (nf, rf) = (n as f64, r as f64);
        let fact = self.rsvd_cost(n, r, Precision::Fp8); // fp8-width traffic
        let fact_time = 2.0 * self.time(&fact, Precision::F16); // f16 math
        let chain = OpCost {
            flops: 2.0 * rf * nf * rf + 2.0 * rf * rf + 2.0 * rf * rf * nf + 2.0 * nf * rf * rf,
            // Factored output: read 4 factors, write 2 (no dense C).
            bytes: 6.0 * nf * rf * 1.0,
            launches: 4.0,
        };
        let chain_time = self.time(&chain, Precision::F16);
        let resident = 3.0 * (2.0 * nf * rf);
        self.finish_timed(
            n,
            fact_time + chain_time,
            fact.then(fact).then(chain),
            resident,
            3.75 / 3.0,
        )
    }

    /// Method 5, warm: cached factors + factored output (steady state).
    pub fn lowrank_auto_warm(&self, n: usize, r: usize) -> SimResult {
        let (nf, rf) = (n as f64, r as f64);
        let chain = OpCost {
            flops: 2.0 * rf * nf * rf + 2.0 * rf * rf + 2.0 * rf * rf * nf + 2.0 * nf * rf * rf,
            bytes: 6.0 * nf * rf * 1.0,
            launches: 4.0,
        };
        let t = self.time(&chain, Precision::F16);
        let resident = 3.0 * (2.0 * nf * rf);
        self.finish_timed(n, t, chain, resident, 3.75 / 3.0)
    }

    fn finish(
        &self,
        n: usize,
        cost: OpCost,
        p: Precision,
        resident_bytes: f64,
        overhead_factor: f64,
    ) -> SimResult {
        let time = self.time(&cost, p);
        self.finish_timed(n, time, cost, resident_bytes, overhead_factor)
    }

    /// Like [`Roofline::finish`] for pipelines whose stages run at
    /// different compute precisions (time already summed per stage).
    fn finish_timed(
        &self,
        n: usize,
        time: f64,
        cost: OpCost,
        resident_bytes: f64,
        overhead_factor: f64,
    ) -> SimResult {
        let useful = 2.0 * sq(n) * n as f64; // dense-equivalent work
        SimResult {
            time_s: time,
            tflops: Roofline::achieved_flops(useful, time) / 1e12,
            // The paper's Table 2 charges workspace at ~overhead_factor×
            // the raw matrix bytes (its own §5.5 "temporary buffers" note).
            peak_memory_bytes: resident_bytes * overhead_factor,
            model_cost: cost,
        }
    }
}

/// Simulated outcome of one method at one size.
#[derive(Clone, Copy, Debug)]
pub struct SimResult {
    /// Simulated wall time (seconds).
    pub time_s: f64,
    /// Achieved dense-equivalent TFLOPS (the paper's reporting convention).
    pub tflops: f64,
    /// Peak resident bytes (Table 2).
    pub peak_memory_bytes: f64,
    /// The raw cost that produced the time.
    pub model_cost: OpCost,
}

fn sq(n: usize) -> f64 {
    n as f64 * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rl() -> Roofline {
        Roofline::new(DeviceProfile::rtx4090())
    }

    #[test]
    fn compute_vs_memory_bound_switch() {
        let r = rl();
        // Tiny op: launch-dominated. Huge op at low intensity: memory-bound.
        let small = OpCost { flops: 1e3, bytes: 1e3, launches: 1.0 };
        let t_small = r.time(&small, Precision::F32);
        assert!((t_small - r.device.launch_overhead_s).abs() < 1e-6);

        let streaming = OpCost { flops: 1e9, bytes: 1e12, launches: 0.0 };
        let t = r.time(&streaming, Precision::F32);
        let mem_t = 1e12 / (r.device.bandwidth_bps * r.device.bandwidth_efficiency);
        assert!((t - mem_t).abs() / mem_t < 1e-9);
    }

    #[test]
    fn dense_f32_matches_paper_order_of_magnitude() {
        // Paper Table 1: PyTorch FP32 ≈ 38-52 TFLOPS across sizes.
        let r = rl();
        for n in [4096usize, 16384] {
            let s = r.pytorch_f32(n);
            assert!(s.tflops > 20.0 && s.tflops < 90.0, "n={n}: {}", s.tflops);
        }
    }

    #[test]
    fn f16_beats_f32_at_scale() {
        let r = rl();
        let f32r = r.pytorch_f32(8192);
        let f16r = r.torchcompile_f16(8192);
        assert!(f16r.tflops > 1.5 * f32r.tflops);
    }

    #[test]
    fn lowrank_auto_wins_at_large_n() {
        // The paper's crossover: LowRank Auto fastest for N ≥ 10240.
        let r = rl();
        let n = 20480;
        let rank = 512;
        let auto = r.lowrank_auto(n, rank);
        let f16 = r.torchcompile_f16(n);
        let fp8 = r.cublas_fp8(n);
        assert!(auto.time_s < f16.time_s, "auto {} vs f16 {}", auto.time_s, f16.time_s);
        assert!(auto.time_s < fp8.time_s);
        // And achieves hundreds of dense-equivalent TFLOPS.
        assert!(auto.tflops > 200.0, "auto tflops {}", auto.tflops);
    }

    #[test]
    fn dense_wins_at_small_n() {
        // Paper: PyTorch FP32 / compiled F16 dominate for N ≤ 4096 because
        // of launch overhead + factorization costs.
        let r = rl();
        let n = 1024;
        let cold = r.lowrank_fp8_cold(n, 64);
        let dense = r.pytorch_f32(n);
        assert!(dense.time_s < cold.time_s, "dense {} cold {}", dense.time_s, cold.time_s);
    }

    #[test]
    fn memory_ordering_matches_table2() {
        let r = rl();
        let n = 20480;
        let m_f32 = r.pytorch_f32(n).peak_memory_bytes;
        let m_f16 = r.torchcompile_f16(n).peak_memory_bytes;
        let m_lr = r.lowrank_fp8(n, 512).peak_memory_bytes;
        assert!(m_f16 < m_f32);
        assert!(m_lr < m_f16);
        // Table 2 ratio: FP32 15 GB vs LowRank 3.75 GB → 4x.
        let ratio = m_f32 / m_lr;
        assert!(ratio > 3.0, "ratio {ratio}");
    }

    #[test]
    fn cost_composition() {
        let a = OpCost { flops: 1.0, bytes: 2.0, launches: 3.0 };
        let b = OpCost { flops: 10.0, bytes: 20.0, launches: 30.0 };
        let c = a.then(b);
        assert_eq!(c.flops, 11.0);
        assert_eq!(c.bytes, 22.0);
        assert_eq!(c.launches, 33.0);
    }

    #[test]
    fn achieved_flops_guards_zero_time() {
        assert_eq!(Roofline::achieved_flops(1e9, 0.0), 0.0);
    }
}
