//! Accuracy-plane suite (PR 7).
//!
//! The observability contract for online error probes: the stochastic
//! estimator tracks the true relative error within a factor of two across
//! sizes, ranks and probe counts (with an exact Eckart–Young anchor on
//! seeded spectra); probing a served workload never changes its bits; and
//! with `[accuracy]` disabled (the default) the serving path performs
//! zero probe work, while the *enabled* plane's steady-state bookkeeping
//! (sampling decision + probe fold-in) allocates nothing per request
//! (counting global-allocator shim, as in `telemetry_plane.rs`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;

use lowrank_gemm::accuracy::{probe_rel_error, AccuracyPlane, ErrorModel, SLO_WINDOW};
use lowrank_gemm::config::AccuracySettings;
use lowrank_gemm::coordinator::{GemmRequest, GemmService, ServiceConfig};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::svd::truncated_svd;
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::lowrank::errors::eckart_young_rel_error;
use lowrank_gemm::metrics::MetricsRegistry;

// ---------------------------------------------------------------------------
// Counting allocator shim: per-thread allocation counters.
// ---------------------------------------------------------------------------

std::thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates everything to `System`; the counter update is a plain
// thread-local store with no allocation of its own (const-initialized TLS).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// Estimator agreement: Eckart–Young anchor on seeded spectra.
// ---------------------------------------------------------------------------

/// Geometric spectrum σ_i = decay^i — a tail heavy enough that truncation
/// error sits in the 1e-2..1e-1 range where factor-of-two bounds bite.
fn spectrum(k: usize, decay: f32) -> Vec<f32> {
    (0..k).map(|i| decay.powi(i as i32)).collect()
}

#[test]
fn probe_tracks_eckart_young_truncation_exactly() {
    // Served output = rank-r truncation of A itself (B = I), where the
    // true relative error is the closed-form Eckart–Young tail of the
    // seeded spectrum — the probe must land within 2x of it.
    let mut rng = Pcg64::seeded(71);
    let sv = spectrum(12, 0.55);
    for (n, r, probes) in [(24, 3, 4), (48, 4, 8), (96, 6, 16)] {
        let a = Matrix::with_spectrum(n, n, &sv, &mut rng);
        let mut b = Matrix::zeros(n, n);
        for i in 0..n {
            b.data_mut()[i * n + i] = 1.0;
        }
        let c = truncated_svd(&a, r).unwrap().reconstruct();
        let expect = eckart_young_rel_error(&sv, r) as f64;
        let est = probe_rel_error(&a, &b, &c, probes, 1000 + n as u64).unwrap();
        assert!(
            est > expect / 2.0 && est < expect * 2.0,
            "n={n} r={r} probes={probes}: probe {est:.3e} vs Eckart–Young {expect:.3e}"
        );
    }
}

#[test]
fn probe_matches_measured_error_across_shapes_ranks_and_probe_counts() {
    let mut rng = Pcg64::seeded(72);
    let sv = spectrum(16, 0.6);
    for n in [32usize, 64, 96] {
        for r in [2usize, 5, 9] {
            for probes in [2usize, 4, 8] {
                let a = Matrix::with_spectrum(n, n, &sv, &mut rng);
                let b = Matrix::gaussian(n, n, &mut rng);
                let exact = a.matmul(&b);
                let served = truncated_svd(&a, r).unwrap().reconstruct().matmul(&b);
                let measured = served.rel_frobenius_distance(&exact) as f64;
                let est =
                    probe_rel_error(&a, &b, &served, probes, (n * r * probes) as u64).unwrap();
                assert!(
                    est > measured / 2.0 && est < measured * 2.0,
                    "n={n} r={r} probes={probes}: probe {est:.3e} vs measured {measured:.3e}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Probing is passive: identical bits with the plane on or off.
// ---------------------------------------------------------------------------

#[test]
fn probed_and_unprobed_serving_is_bitwise_identical() {
    let run = |enabled: bool| -> Vec<Matrix> {
        let svc = GemmService::start(ServiceConfig {
            accuracy: AccuracySettings {
                enabled,
                sample_every: 1,
                probes: 4,
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        let mut rng = Pcg64::seeded(73);
        let mut out = Vec::new();
        for kind in [
            KernelKind::DenseF32,
            KernelKind::DenseFp8,
            KernelKind::LowRankFp8,
        ] {
            let a = Matrix::low_rank_noisy(160, 160, 6, 1e-4, &mut rng);
            let b = Matrix::low_rank_noisy(160, 160, 6, 1e-4, &mut rng);
            let resp = svc
                .gemm_blocking(GemmRequest::new(a, b).with_kernel(kind))
                .unwrap();
            out.push(resp.c);
        }
        out
    };
    let off = run(false);
    let on = run(true);
    for (i, (a, b)) in off.iter().zip(&on).enumerate() {
        assert_eq!(a.data(), b.data(), "request {i}: probing changed bits");
    }
}

#[test]
fn disabled_plane_schedules_no_probe_work() {
    let svc = GemmService::start(ServiceConfig::default()).unwrap();
    let mut rng = Pcg64::seeded(74);
    for _ in 0..4 {
        let a = Matrix::gaussian(48, 48, &mut rng);
        let b = Matrix::gaussian(48, 48, &mut rng);
        svc.gemm_blocking(GemmRequest::new(a, b)).unwrap();
    }
    assert!(svc.accuracy().is_none());
    assert!(svc.stats().accuracy.is_none());
    let counters = svc.metrics().counters();
    assert!(
        !counters.keys().any(|k| k.starts_with("accuracy.")),
        "disabled plane must not even intern accuracy metrics: {counters:?}"
    );
}

// ---------------------------------------------------------------------------
// Enabled plane, steady state: the per-request bookkeeping (sampling
// decision + probe fold-in) is allocation-free once the SLO window and
// model cell exist.
// ---------------------------------------------------------------------------

#[test]
fn probe_bookkeeping_hot_path_is_allocation_free() {
    let registry = MetricsRegistry::new();
    let plane = AccuracyPlane::new(
        AccuracySettings {
            enabled: true,
            sample_every: 4,
            probes: 4,
            ..Default::default()
        },
        Arc::new(ErrorModel::new(0.2, 5)),
        &registry,
    );
    // Warmup: create the model cell and fill the SLO window to capacity,
    // so steady-state records pop+push without growing the deque.
    for _ in 0..SLO_WINDOW {
        plane.observe(KernelKind::LowRankFp8, 512, 512, 512, 16, 1e-2, 2e-2, 0.05, 3.0);
    }
    let before = thread_allocs();
    for i in 0..1000u64 {
        let _ = plane.sample();
        let _ = plane.probe_seed(i);
        plane.observe(KernelKind::LowRankFp8, 512, 512, 512, 16, 1e-2, 2e-2, 0.05, 3.0);
    }
    let after = thread_allocs();
    assert_eq!(
        after - before,
        0,
        "steady-state probe bookkeeping must not allocate"
    );
    assert_eq!(plane.stats().probed, SLO_WINDOW as u64 + 1000);
}
