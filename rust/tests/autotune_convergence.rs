//! End-to-end tests for the online autotuning plane: calibration
//! convergence against a synthetically skewed backend, default-off
//! bit-identity, exploration accounting, and save/load warm-starts
//! through a full service restart.

use std::sync::Arc;

use lowrank_gemm::autotune::CalibrationTable;
use lowrank_gemm::coordinator::{GemmRequest, GemmService, ServiceConfig};
use lowrank_gemm::gpu_sim::DeviceProfile;
use lowrank_gemm::kernels::{AutoKernelSelector, SelectorInputs};
use lowrank_gemm::linalg::{Matrix, Pcg64};

fn inputs(n: usize) -> SelectorInputs {
    SelectorInputs {
        m: n,
        k: n,
        n,
        error_tolerance: 0.05,
        rank: (n / 40).max(16),
        factors_cached: true,
        factored_output_ok: true,
        decomp_amortization: 1.0,
        fp8_reencode: false,
    }
}

fn temp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("lrg-autotune-{tag}-{}.json", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

fn rand_req(n: usize, seed: u64) -> GemmRequest {
    let mut rng = Pcg64::seeded(seed);
    GemmRequest::new(
        Matrix::gaussian(n, n, &mut rng),
        Matrix::gaussian(n, n, &mut rng),
    )
}

/// The headline loop: one kernel secretly runs 50x slower than the
/// analytic model believes; every other kernel behaves exactly as
/// modeled. Feeding measured samples back through the calibration table
/// must flip the selector's ranking away from the mispredicted kernel.
#[test]
fn skewed_backend_flips_the_selectors_ranking() {
    let table = Arc::new(CalibrationTable::new(0.2, 5));
    let selector =
        AutoKernelSelector::new(DeviceProfile::rtx4090()).with_calibration(table.clone());
    let inp = inputs(4096);
    let baseline = selector.select(&inp);
    let skew = 50.0;

    // Before any samples: the analytic prior rules, correction is 1.
    assert_eq!(baseline.calibration, 1.0);

    let mut flipped_at = None;
    for round in 1..=200u32 {
        // Simulate one serving round: every kernel gets a measured
        // sample (the ε-greedy policy's job in live serving); only the
        // baseline kernel's measurement deviates from the model.
        for c in selector.ranked(&inp) {
            let raw = c.cost.time_s / c.calibration;
            let observed = if c.kind == baseline.kind {
                raw * skew
            } else {
                raw
            };
            table.record(c.kind, inp.m, inp.k, inp.n, raw, observed);
        }
        if round == 1 {
            // A single sample must NOT be trusted outright: with prior
            // strength 5 the blended correction is (5 + 50)/6 ≈ 9.2,
            // well short of the measured 50x.
            let c1 = table.correction(baseline.kind, inp.m, inp.k, inp.n);
            assert!(
                c1 < skew / 2.0,
                "one sample over-trusted: correction {c1}"
            );
        }
        if selector.select(&inp).kind != baseline.kind {
            flipped_at = Some(round);
            break;
        }
    }
    let flipped_at = flipped_at.expect("a 50x skew must flip the ranking within 200 samples");

    let corrected = selector.select(&inp);
    assert_ne!(corrected.kind, baseline.kind);
    // The flip reflects reality: under the true (skewed) wall times the
    // new choice is genuinely faster than the old one.
    let true_baseline = (baseline.cost.time_s) * skew;
    let raw_corrected = corrected.cost.time_s / corrected.calibration;
    assert!(
        raw_corrected < true_baseline,
        "flip must pick a kernel that is actually faster \
         ({raw_corrected} vs true {true_baseline}, flipped at {flipped_at})"
    );
    // And with enough consistent samples, the correction approaches the
    // true ratio.
    for _ in 0..100 {
        let c = selector.estimate(baseline.kind, &inp);
        let raw = c.cost.time_s / c.calibration;
        table.record(baseline.kind, inp.m, inp.k, inp.n, raw, raw * skew);
    }
    let settled = table.correction(baseline.kind, inp.m, inp.k, inp.n);
    assert!(
        (settled / skew - 1.0).abs() < 0.2,
        "correction should settle near the true skew: {settled} vs {skew}"
    );
}

/// Acceptance gate: with autotune disabled (the default config), routing
/// is bit-identical to the static analytic model — enabled-but-unsampled
/// must match too.
#[test]
fn default_off_routing_is_bit_identical() {
    let off = GemmService::start(ServiceConfig::default()).unwrap();
    let mut cfg = ServiceConfig::default();
    cfg.autotune.enabled = true;
    cfg.autotune.epsilon = 0.0;
    let on = GemmService::start(cfg).unwrap();

    for (i, n) in [32usize, 96, 256, 1024].into_iter().enumerate() {
        let req = rand_req(n, 900 + i as u64);
        let a = off.plan(&req);
        let b = on.plan(&req);
        assert_eq!(a.choice.kind, b.choice.kind, "n={n}");
        assert_eq!(
            a.choice.cost.time_s.to_bits(),
            b.choice.cost.time_s.to_bits(),
            "n={n}: unsampled calibration must not move a single bit"
        );
        assert_eq!(b.choice.calibration, 1.0);
        assert!(!a.explored && !b.explored);
    }
}

/// ε = 1 forces every auto-routed request to explore; the service must
/// count those explorations and keep results correct (exploration trades
/// latency, never accuracy).
#[test]
fn exploration_is_counted_and_stays_correct() {
    let mut cfg = ServiceConfig::default();
    cfg.autotune.enabled = true;
    cfg.autotune.epsilon = 1.0;
    let svc = GemmService::start(cfg).unwrap();

    for i in 0..6 {
        // Low-rank-friendly operands: any in-tolerance kernel the policy
        // explores (including the factor chain) must stay accurate.
        let mut rng = Pcg64::seeded(700 + i);
        let req = GemmRequest::new(
            Matrix::low_rank_noisy(48, 48, 6, 1e-4, &mut rng),
            Matrix::low_rank_noisy(48, 48, 6, 1e-4, &mut rng),
        );
        let exact = req.a.matmul(&req.b);
        let resp = svc.gemm_blocking(req).unwrap();
        assert!(resp.c.rel_frobenius_distance(&exact) < 0.1);
    }
    let counters = svc.metrics().counters();
    let explored = counters.get("autotune.explore_total").copied().unwrap_or(0);
    assert!(explored >= 1, "ε=1 must explore: counters {counters:?}");
    // Exploration feeds the table: explored kernels' cells exist.
    assert!(!svc.calibration().unwrap().is_empty());
}

/// Full restart cycle: a tuned service persists its table on shutdown
/// and the next instance warm-starts from it bit-exactly.
#[test]
fn calibration_survives_a_service_restart() {
    let path = temp_path("restart");
    let _ = std::fs::remove_file(&path);
    let mut cfg = ServiceConfig::default();
    cfg.autotune.enabled = true;
    cfg.autotune.epsilon = 0.0;
    cfg.autotune.table_path = Some(path.clone());

    let svc = GemmService::start(cfg.clone()).unwrap();
    for i in 0..6 {
        svc.gemm_blocking(rand_req(48, 500 + i)).unwrap();
    }
    let mut before = svc.calibration().unwrap().snapshot();
    assert!(!before.is_empty(), "requests must populate the table");
    drop(svc); // persists the table

    assert!(std::path::Path::new(&path).exists(), "drop must save");

    let svc2 = GemmService::start(cfg).unwrap();
    let mut after = svc2.calibration().unwrap().snapshot();
    before.sort_by_key(|(k, _)| (k.kind.id(), k.size_class));
    after.sort_by_key(|(k, _)| (k.kind.id(), k.size_class));
    assert_eq!(before, after, "warm start must reload bit-exactly");
    assert!(
        svc2.metrics()
            .counters()
            .get("autotune.warm_start_entries")
            .copied()
            .unwrap_or(0)
            >= 1
    );
    drop(svc2);
    let _ = std::fs::remove_file(&path);
}

/// A corrupt persisted table fails startup loudly instead of silently
/// serving uncalibrated.
#[test]
fn corrupt_calibration_file_fails_start() {
    let path = temp_path("corrupt");
    std::fs::write(&path, "{not json").unwrap();
    let mut cfg = ServiceConfig::default();
    cfg.autotune.enabled = true;
    cfg.autotune.table_path = Some(path.clone());
    assert!(GemmService::start(cfg).is_err());
    let _ = std::fs::remove_file(&path);
}
