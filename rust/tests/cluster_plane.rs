//! Cluster-tier integration tests: an in-process router plus real node
//! agents on loopback sockets (`[cluster]`).
//!
//! The contract under test: heartbeat silence walks a node down the
//! Alive → Suspect → Dead ladder and traffic fails over without losing
//! a single request; a fingerprint re-homes when its owner leaves and
//! cold-fills at most once per new owner; transport faults (refused
//! connections, both injected and real) retry with backoff to the
//! next-best node under breaker control; a draining node deregisters
//! first and completes its in-flight work; and a cluster-routed result
//! is bitwise-identical to the same request served single-process.

use std::thread;
use std::time::{Duration, Instant};

use lowrank_gemm::cache::Fingerprint;
use lowrank_gemm::cluster::{NodeAgent, RouterTier};
use lowrank_gemm::config::AppConfig;
use lowrank_gemm::coordinator::{GemmRequest, GemmService, ServiceConfig};
use lowrank_gemm::error::Error;
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::metrics::MetricsRegistry;

/// Fast-cadence cluster config: ephemeral router port, 40 ms heartbeats,
/// Suspect at 160 ms silence, Dead at 400 ms.
fn router_app() -> AppConfig {
    let mut app = AppConfig::default();
    app.cluster.enabled = true;
    app.cluster.router_addr = "127.0.0.1:0".into();
    app.cluster.node_addr = "127.0.0.1:0".into();
    app.cluster.heartbeat_ms = 40;
    app.cluster.heartbeat_timeout_ms = 160;
    app.cluster.dead_after_ms = 400;
    app.cluster.read_timeout_ms = 4000;
    app.cluster.backoff_base_ms = 1;
    app.cluster.backoff_cap_ms = 8;
    app.service.workers = 2;
    app
}

fn node_app(router_addr: &str) -> AppConfig {
    let mut app = router_app();
    app.cluster.router_addr = router_addr.into();
    app
}

fn counter(m: &MetricsRegistry, name: &str) -> u64 {
    m.counters().get(name).copied().unwrap_or(0)
}

fn square(n: usize, rng: &mut Pcg64) -> Matrix {
    Matrix::gaussian(n, n, rng)
}

#[test]
fn routed_result_is_bitwise_identical_to_single_process() {
    let router = RouterTier::start(&router_app()).expect("router");
    let app = node_app(router.addr());
    let _node = NodeAgent::start(&app).expect("node");

    let mut rng = Pcg64::seeded(11);
    let a = square(96, &mut rng);
    let b = square(96, &mut rng);
    let reply = router.exec(&a, &b, None).expect("routed exec");

    // The same request through a single-process service built from the
    // same config: identical kernel choice, identical result bits.
    let svc = GemmService::start(ServiceConfig::from_app(&app).expect("cfg")).expect("svc");
    let resp = svc
        .gemm_blocking(GemmRequest::new(a.clone(), b.clone()))
        .expect("local exec");

    assert_eq!(reply.kernel, resp.kernel.id(), "kernel choice diverged");
    assert_eq!(
        (reply.c.rows(), reply.c.cols()),
        (resp.c.rows(), resp.c.cols()),
        "shape diverged"
    );
    let same = reply
        .c
        .data()
        .iter()
        .zip(resp.c.data())
        .all(|(x, y)| x.to_bits() == y.to_bits());
    assert!(same, "cluster-routed result bits differ from single-process");
}

#[test]
fn heartbeat_silence_walks_suspect_to_dead_and_traffic_fails_over() {
    let router = RouterTier::start(&router_app()).expect("router");
    let good = NodeAgent::start(&node_app(router.addr())).expect("good node");

    // This node registers, then drops *every* heartbeat (seeded injection
    // with probability 1): the router hears silence without the process
    // dying — exactly the partition the health ladder is for.
    let mut bad_cfg = node_app(router.addr());
    bad_cfg.fault.inject.seed = 1;
    bad_cfg.fault.inject.net_heartbeat_drop = 1.0;
    let bad = NodeAgent::start(&bad_cfg).expect("bad node");
    assert_eq!(router.registry().len(), 2);
    let bad_id = bad.node_id();

    // Silence ≥ dead_after_ms removes the node and evicts its affinity.
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.registry().len() > 1 && Instant::now() < deadline {
        thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(router.registry().len(), 1, "silent node should be removed");
    let views = router.registry().views();
    assert_eq!(views[0].id, good.node_id());
    assert!(views.iter().all(|v| v.id != bad_id));
    assert!(counter(router.metrics(), "cluster.node.suspect") >= 1);
    assert!(counter(router.metrics(), "cluster.node.dead") >= 1);

    // Zero lost requests through the failover: everything resolves, and
    // with one healthy node left, everything resolves *ok*.
    let report = router.run_workload(8, 64, 3);
    assert_eq!(report.resolved(), report.requests, "requests lost");
    assert_eq!(report.ok, report.requests, "requests failed after failover");
    drop(bad);
}

#[test]
fn refused_connections_retry_with_backoff_to_next_best_node() {
    // Keep the phantom Alive for the whole test so the least-loaded
    // ranking keeps offering it first: long health timeouts.
    let mut app = router_app();
    app.cluster.heartbeat_timeout_ms = 10_000;
    app.cluster.dead_after_ms = 20_000;
    let router = RouterTier::start(&app).expect("router");

    // A phantom node on a dead port, advertising more capacity than the
    // real node: anonymous routing prefers it, every dial is refused,
    // and the attempt loop must back off and fail over.
    router
        .registry()
        .register("127.0.0.1:9", 8, Instant::now());
    let mut napp = node_app(router.addr());
    napp.cluster.heartbeat_timeout_ms = 10_000;
    napp.cluster.dead_after_ms = 20_000;
    let _node = NodeAgent::start(&napp).expect("node");

    let mut rng = Pcg64::seeded(5);
    for i in 0..6 {
        let a = square(48, &mut rng);
        let b = square(48, &mut rng);
        let reply = router.exec(&a, &b, None);
        assert!(reply.is_ok(), "request {i} did not fail over: {reply:?}");
    }
    let m = router.metrics();
    assert!(counter(m, "cluster.rpc.retry") >= 1, "no retries recorded");
    assert!(counter(m, "cluster.failover") >= 1, "no failover recorded");
    let transport_failures =
        counter(m, "cluster.rpc.error") + counter(m, "cluster.rpc.timeout");
    assert!(
        transport_failures >= 1,
        "refused dials should count as transport failures"
    );
    assert_eq!(counter(m, "cluster.rpc.ok"), 6);
    // The phantom's breaker absorbed the failures (it trips after 3 in
    // its window, so at most a handful of dials ever reached the dead
    // port across 6 requests).
    assert!(
        transport_failures <= 4,
        "breaker should stop dialing the dead node"
    );
}

#[test]
fn injected_refusals_exhaust_attempts_deterministically() {
    // Router-side injection refusing every (node, attempt) draw: the
    // attempt loop must walk all max_attempts with backoff and surface a
    // typed NodeUnavailable — never a hang, never a lost request.
    let mut app = router_app();
    app.fault.inject.seed = 7;
    app.fault.inject.net_refuse = 1.0;
    let router = RouterTier::start(&app).expect("router");
    let _node = NodeAgent::start(&node_app(router.addr())).expect("node");

    let mut rng = Pcg64::seeded(21);
    let a = square(48, &mut rng);
    let b = square(48, &mut rng);
    match router.exec(&a, &b, None) {
        Err(Error::NodeUnavailable(_)) => {}
        other => panic!("expected NodeUnavailable after exhausted attempts, got {other:?}"),
    }
    let m = router.metrics();
    assert_eq!(counter(m, "cluster.rpc.attempt"), 3, "default max_attempts");
    assert_eq!(counter(m, "cluster.rpc.retry"), 2);
    assert_eq!(counter(m, "cluster.rpc.ok"), 0);
    assert_eq!(counter(m, "cluster.rpc.error"), 3);
}

#[test]
fn rehomed_fingerprint_cold_fills_at_most_once_per_owner() {
    let mut app = router_app();
    app.cluster.affinity_min_dim = 32;
    app.cache.enabled = true;
    app.cache.min_dim = 32;
    let router = RouterTier::start(&app).expect("router");
    let mut napp = app.clone();
    napp.cluster.router_addr = router.addr().into();
    let mut node1 = NodeAgent::start(&napp).expect("node1");
    let node2 = NodeAgent::start(&napp).expect("node2");

    let mut rng = Pcg64::seeded(9);
    // The reused "weight" operand: low-rank so the factor chain caches it.
    let b = Matrix::low_rank_noisy(64, 64, 4, 1e-5, &mut rng);
    let fp = Fingerprint::of(&b);
    let m = router.metrics();

    // Warms one node's content cache with b's factors (the forced
    // low-rank kernel is the deterministic put path, independent of the
    // cost model's natural choice for 64-class shapes), then waits for
    // its heartbeat digest to land in the router's affinity map.
    let warm = |node: &NodeAgent, registry: &lowrank_gemm::cluster::NodeRegistry| {
        let mut r = Pcg64::seeded(77);
        let x = Matrix::low_rank_noisy(64, 64, 4, 1e-5, &mut r);
        node.service()
            .gemm_blocking(
                GemmRequest::new(x, b.clone()).with_kernel(KernelKind::LowRankFp8),
            )
            .expect("warm-up exec");
        let deadline = Instant::now() + Duration::from_secs(5);
        while Instant::now() < deadline {
            let c = registry.candidates(Some(fp));
            if c[0].id == node.node_id() && c[0].resident {
                return;
            }
            thread::sleep(Duration::from_millis(20));
        }
        panic!("heartbeat digest never reported the fingerprint resident");
    };

    // Designate node1 the owner: once its digest lands, affinity routes
    // every request for b there and nothing ever cold-fills.
    warm(&node1, router.registry());
    for _ in 0..4 {
        router.exec(&square(64, &mut rng), &b, None).expect("warm exec");
    }
    assert_eq!(
        counter(m, "cluster.refill.start"),
        0,
        "warm affinity hits must not fill"
    );
    assert!(counter(m, "cluster.route.affinity") >= 4);

    // The owner leaves gracefully: the fingerprint re-homes to the
    // survivor and cold-fills exactly once there.
    node1.shutdown();
    assert_eq!(router.registry().len(), 1);
    router.exec(&square(64, &mut rng), &b, None).expect("re-homed exec");
    assert_eq!(
        counter(m, "cluster.refill.start"),
        1,
        "re-homing is one cold fill on the new owner"
    );
    // Once the survivor is warm and its digest lands, traffic stays warm.
    warm(&node2, router.registry());
    for _ in 0..3 {
        router.exec(&square(64, &mut rng), &b, None).expect("warm exec 2");
    }
    assert_eq!(
        counter(m, "cluster.refill.start"),
        1,
        "the new owner must serve warm after one fill"
    );
}

#[test]
fn drain_deregisters_first_and_completes_in_flight_work() {
    let router = RouterTier::start(&router_app()).expect("router");
    let _node1 = NodeAgent::start(&node_app(router.addr())).expect("node1");
    let mut node2 = NodeAgent::start(&node_app(router.addr())).expect("node2");
    assert_eq!(router.registry().len(), 2);

    // Requests race the drain from worker threads; every one must
    // resolve ok — served by the draining node (in-flight work finishes
    // behind the deregister) or failed over to the survivor.
    let router_ref = &router;
    thread::scope(|s| {
        let handles: Vec<_> = (0..6u64)
            .map(|i| {
                s.spawn(move || {
                    let mut rng = Pcg64::seeded(100 + i);
                    let a = square(64, &mut rng);
                    let b = square(64, &mut rng);
                    router_ref.exec(&a, &b, None)
                })
            })
            .collect();
        thread::sleep(Duration::from_millis(5));
        node2.shutdown();
        for (i, h) in handles.into_iter().enumerate() {
            let r = h.join().expect("worker thread");
            assert!(r.is_ok(), "request {i} lost across the drain: {r:?}");
        }
    });
    assert_eq!(router.registry().len(), 1, "drained node should be deregistered");
    assert_eq!(counter(router.metrics(), "cluster.node.deregister"), 1);
}
