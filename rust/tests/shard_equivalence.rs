//! End-to-end tests of the shard execution plane through the coordinator:
//! large requests run block-partitioned across ≥ 2 workers (observable in
//! the per-shard metrics) and reproduce the single-threaded kernels
//! bit-for-bit; small requests never pay the tiling overhead.

use lowrank_gemm::config::ShardSettings;
use lowrank_gemm::coordinator::{GemmRequest, GemmService, ServiceConfig};
use lowrank_gemm::fp8::quantized_matmul;
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::{gemm_blocked, Matrix, Pcg64};

fn sharded_service(workers: usize, min_parallel_n: usize) -> GemmService {
    let cfg = ServiceConfig {
        shard: ShardSettings {
            workers,
            tile_m: 256,
            tile_n: 256,
            min_parallel_n,
        },
        ..Default::default()
    };
    GemmService::start(cfg).expect("service boots")
}

#[test]
fn large_dense_request_is_sharded_and_bitwise_exact() {
    let svc = sharded_service(4, 256);
    let mut rng = Pcg64::seeded(501);
    let a = Matrix::gaussian(512, 512, &mut rng);
    let b = Matrix::gaussian(512, 512, &mut rng);
    let req = GemmRequest::new(a.clone(), b.clone()).with_kernel(KernelKind::DenseF32);
    let resp = svc.gemm_blocking(req).unwrap();

    let serial = gemm_blocked(&a, &b).unwrap();
    assert_eq!(
        resp.c.data(),
        serial.data(),
        "sharded result must match the single-threaded kernel bit-for-bit"
    );

    let counters = svc.metrics().counters();
    assert!(
        counters.get("shard.gemm.parallel").copied().unwrap_or(0) >= 1,
        "large request must take the parallel path: {counters:?}"
    );
    assert_eq!(counters.get("shard.tasks").copied(), Some(4), "2×2 grid");
    let hists = svc.metrics().histogram_summaries();
    assert!(
        hists.get("shard.tile_us").map(|h| h.count).unwrap_or(0) >= 4,
        "per-shard latency histogram must record every tile"
    );
}

#[test]
fn heavy_request_engages_multiple_workers() {
    let svc = sharded_service(4, 256);
    let mut rng = Pcg64::seeded(502);
    // 768² → a 3×3 tile grid: nine ~100 ms tasks, four claim jobs. Even on
    // one core the OS timeslices the claim jobs long before a single
    // worker could drain nine tiles.
    let a = Matrix::gaussian(768, 768, &mut rng);
    let b = Matrix::gaussian(768, 768, &mut rng);
    let req = GemmRequest::new(a, b).with_kernel(KernelKind::DenseF32);
    svc.gemm_blocking(req).unwrap();

    let counters = svc.metrics().counters();
    let engaged = counters
        .iter()
        .filter(|(k, v)| k.starts_with("shard.worker.") && **v > 0)
        .count();
    assert!(
        engaged >= 2,
        "expected ≥ 2 workers to claim tiles, got {engaged}: {counters:?}"
    );
    let tiles: u64 = counters
        .iter()
        .filter(|(k, _)| k.starts_with("shard.worker."))
        .map(|(_, v)| *v)
        .sum();
    assert_eq!(tiles, 9, "all nine tiles attributed to workers");
}

#[test]
fn small_requests_stay_single_threaded() {
    let svc = sharded_service(4, 512);
    let mut rng = Pcg64::seeded(503);
    let a = Matrix::gaussian(64, 64, &mut rng);
    let b = Matrix::gaussian(64, 64, &mut rng);
    let req = GemmRequest::new(a.clone(), b.clone()).with_kernel(KernelKind::DenseF32);
    let resp = svc.gemm_blocking(req).unwrap();
    assert!(resp.c.rel_frobenius_distance(&a.matmul(&b)) < 1e-6);

    let counters = svc.metrics().counters();
    assert_eq!(counters.get("shard.gemm.parallel"), None);
    assert!(counters.get("shard.gemm.serial").copied().unwrap_or(0) >= 1);
}

#[test]
fn fp8_request_is_sharded_and_bitwise_exact() {
    let svc = sharded_service(3, 256);
    let mut rng = Pcg64::seeded(504);
    let a = Matrix::gaussian(320, 256, &mut rng);
    let b = Matrix::gaussian(256, 320, &mut rng);
    let req = GemmRequest::new(a.clone(), b.clone()).with_kernel(KernelKind::DenseFp8);
    let resp = svc.gemm_blocking(req).unwrap();

    let serial = quantized_matmul(
        &a,
        &b,
        lowrank_gemm::fp8::StorageFormat::Fp8(lowrank_gemm::fp8::Fp8Format::E4M3),
    );
    assert_eq!(resp.c.data(), serial.data());
}

#[test]
fn lowrank_request_runs_panel_parallel_factorization() {
    let svc = sharded_service(4, 256);
    let mut rng = Pcg64::seeded(505);
    let w = Matrix::low_rank_noisy(640, 640, 10, 1e-4, &mut rng);
    svc.preload_factor(1, &w).unwrap();
    let x = Matrix::gaussian(640, 640, &mut rng);
    let req = GemmRequest::new(x.clone(), w.clone())
        .with_ids(None, Some(1))
        .with_kernel(KernelKind::LowRankAuto);
    let resp = svc.gemm_blocking(req).unwrap();
    assert!(resp.rank >= 1);
    let exact = x.matmul(&w);
    assert!(
        resp.c.rel_frobenius_distance(&exact) < 0.05,
        "err {}",
        resp.c.rel_frobenius_distance(&exact)
    );
    // The offline factorization itself ran on the tile plane.
    let counters = svc.metrics().counters();
    assert!(
        counters.get("shard.gemm.parallel").copied().unwrap_or(0) >= 1,
        "panel-parallel rSVD sketch expected: {counters:?}"
    );
}
