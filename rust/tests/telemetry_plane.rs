//! Telemetry-plane suite (PR 6).
//!
//! The tracing + metrics contract: concurrent lock-free recording matches
//! serial totals; the flight recorder's ring wraps while slowest-K
//! retention survives eviction; a sharded request's span tree covers
//! route → exec → pack → per-worker tiles → assemble with every parent
//! resolving; and with `[trace]` disabled (the default) results stay
//! bitwise identical while the span sites and metric handles perform
//! **zero** heap allocations at steady state (counting global-allocator
//! shim, per-thread counters as in `pack_equivalence.rs`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;

use lowrank_gemm::config::TraceSettings;
use lowrank_gemm::coordinator::{GemmRequest, GemmService, ServiceConfig};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::metrics::MetricsRegistry;
use lowrank_gemm::trace_plane::{self, export, AttrValue, NO_PARENT};

// ---------------------------------------------------------------------------
// Counting allocator shim: per-thread allocation counters.
// ---------------------------------------------------------------------------

std::thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates everything to `System`; the counter update is a plain
// thread-local store with no allocation of its own (const-initialized TLS).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

fn traced_config(trace: TraceSettings) -> ServiceConfig {
    ServiceConfig {
        trace,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// Lock-free metrics: concurrent recording matches serial totals.
// ---------------------------------------------------------------------------

#[test]
fn concurrent_recording_matches_serial_totals() {
    let registry = Arc::new(MetricsRegistry::new());
    let counter = registry.counter("par.counter");
    let hist = registry.histogram("par.hist");
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 5000;
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let (counter, hist) = (counter.clone(), hist.clone());
            std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    counter.inc();
                    hist.observe((t * PER_THREAD + i + 1) as f64);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let snap = registry.snapshot();
    let n = THREADS * PER_THREAD;
    assert_eq!(snap.counters["par.counter"], n);
    let s = snap.histograms["par.hist"];
    assert_eq!(s.count, n);
    assert_eq!(s.dropped, 0);
    assert_eq!(s.max, n as f64);
    // Samples were 1..=n, so the merged mean is (n+1)/2 — stripe merging
    // must lose nothing.
    let expect = (n + 1) as f64 / 2.0;
    assert!(
        (s.mean - expect).abs() / expect < 1e-9,
        "merged mean {} != {expect}",
        s.mean
    );
}

// ---------------------------------------------------------------------------
// Flight recorder at the service level: ring wrap + slowest-K retention.
// ---------------------------------------------------------------------------

#[test]
fn flight_recorder_wraps_and_keeps_slowest() {
    let svc = GemmService::start(traced_config(TraceSettings {
        enabled: true,
        ring_capacity: 4,
        slowest_k: 2,
        ..Default::default()
    }))
    .unwrap();
    let mut rng = Pcg64::seeded(601);
    // One heavy request first (trace id 1), then enough light ones to
    // wrap the 4-slot ring past it.
    let a = Matrix::gaussian(320, 320, &mut rng);
    let b = Matrix::gaussian(320, 320, &mut rng);
    svc.gemm_blocking(GemmRequest::new(a, b).with_kernel(KernelKind::DenseF32))
        .unwrap();
    for _ in 0..6 {
        let a = Matrix::gaussian(32, 32, &mut rng);
        let b = Matrix::gaussian(32, 32, &mut rng);
        svc.gemm_blocking(GemmRequest::new(a, b).with_kernel(KernelKind::DenseF32))
            .unwrap();
    }
    let rec = svc.tracer().recorder();
    assert_eq!(rec.total_recorded(), 7);
    let recent = rec.recent();
    assert_eq!(recent.len(), 4);
    assert!(
        recent.iter().all(|t| t.trace_id >= 4),
        "ring keeps the last 4"
    );
    let slow = rec.slowest();
    assert_eq!(slow.len(), 2);
    assert!(slow[0].duration_ns >= slow[1].duration_ns);
    assert!(
        slow.iter().any(|t| t.trace_id == 1),
        "the heavy request must survive ring eviction: {:?}",
        slow.iter()
            .map(|t| (t.trace_id, t.duration_ns))
            .collect::<Vec<_>>()
    );
}

// ---------------------------------------------------------------------------
// Span-tree integrity across worker threads.
// ---------------------------------------------------------------------------

fn u64_attr(s: &lowrank_gemm::trace_plane::SpanRecord, key: &str) -> Option<u64> {
    s.attrs().find(|a| a.key == key).map(|a| match a.value {
        AttrValue::U64(v) => v,
        other => panic!("attr {key} is not u64: {other:?}"),
    })
}

#[test]
fn sharded_request_span_tree_is_complete() {
    let svc = GemmService::start(traced_config(TraceSettings {
        enabled: true,
        ..Default::default()
    }))
    .unwrap();
    let mut rng = Pcg64::seeded(602);
    // 512×512 over the default 256×256 grid and 4 shard workers: the
    // parallel gates pass and the product fans out as exactly 4 tiles.
    let a = Matrix::gaussian(512, 512, &mut rng);
    let b = Matrix::gaussian(512, 512, &mut rng);
    svc.gemm_blocking(GemmRequest::new(a, b).with_kernel(KernelKind::DenseF32))
        .unwrap();

    let traces = svc.tracer().recorder().recent();
    assert_eq!(traces.len(), 1);
    let t = &traces[0];
    assert_eq!(t.dropped_spans, 0);
    let spans = &t.spans;

    // Every non-root parent id resolves to a recorded span.
    for s in spans.iter() {
        if s.parent_id != NO_PARENT {
            assert!(
                spans.iter().any(|p| p.span_id == s.parent_id),
                "span `{}` ({}) has unresolved parent {}",
                s.name,
                s.span_id,
                s.parent_id
            );
        }
    }

    let find = |name: &str| spans.iter().find(|s| s.name == name);
    let root = find("request").expect("root span");
    assert_eq!(root.parent_id, NO_PARENT);
    let route = find("route").expect("route span");
    assert_eq!(route.parent_id, root.span_id);
    find("queue").expect("queue span");
    let exec = find("exec").expect("exec span");
    assert_eq!(exec.parent_id, root.span_id);

    let packs: Vec<_> = spans.iter().filter(|s| s.name == "pack").collect();
    assert!(!packs.is_empty(), "aligned sharded gemm must record a pack");
    assert!(packs.iter().all(|s| s.parent_id == exec.span_id));

    let tiles: Vec<_> = spans.iter().filter(|s| s.name == "tile").collect();
    assert_eq!(tiles.len(), 4, "512×512 over 256×256 tiles is 4 tasks");
    let mut tile_ids: Vec<u64> = Vec::new();
    for tile in &tiles {
        assert_eq!(tile.parent_id, exec.span_id, "tiles attach under exec");
        assert!(
            tile.start_ns >= exec.start_ns && tile.end_ns <= exec.end_ns,
            "tile span must nest inside exec in time"
        );
        u64_attr(tile, "worker").expect("tile carries its claim worker");
        tile_ids.push(u64_attr(tile, "tile").expect("tile index attr"));
    }
    tile_ids.sort_unstable();
    assert_eq!(tile_ids, vec![0, 1, 2, 3], "each task traced exactly once");

    let assemble = find("assemble").expect("assemble span");
    assert_eq!(assemble.parent_id, exec.span_id);

    // The trace round-trips through the chrome exporter.
    let json = export::chrome_trace_json(&traces);
    assert!(json.starts_with("{\"traceEvents\":["));
    assert!(json.contains("\"name\":\"tile\""));
    assert!(json.contains("\"name\":\"assemble\""));
}

#[test]
fn lowrank_request_records_factor_spans() {
    let svc = GemmService::start(traced_config(TraceSettings {
        enabled: true,
        ..Default::default()
    }))
    .unwrap();
    let mut rng = Pcg64::seeded(603);
    let a = Matrix::low_rank_noisy(96, 96, 6, 1e-5, &mut rng);
    let b = Matrix::low_rank_noisy(96, 96, 6, 1e-5, &mut rng);
    svc.gemm_blocking(GemmRequest::new(a, b).with_kernel(KernelKind::LowRankFp8))
        .unwrap();
    let traces = svc.tracer().recorder().recent();
    let spans = &traces[0].spans;
    let factors = spans.iter().filter(|s| s.name == "factor").count();
    assert_eq!(factors, 2, "one factor span per operand");
    assert!(
        spans.iter().any(|s| s.name == "decompose"),
        "cold factorization must record decompose: {:?}",
        spans.iter().map(|s| s.name).collect::<Vec<_>>()
    );
}

// ---------------------------------------------------------------------------
// Disabled tracing: bitwise-identical results, zero-allocation span sites.
// ---------------------------------------------------------------------------

#[test]
fn disabled_tracing_is_bitwise_invisible() {
    let run = |enabled: bool| -> Vec<Matrix> {
        let svc = GemmService::start(traced_config(TraceSettings {
            enabled,
            ..Default::default()
        }))
        .unwrap();
        let mut rng = Pcg64::seeded(604);
        let mut out = Vec::new();
        for kind in [
            KernelKind::DenseF32,
            KernelKind::DenseFp8,
            KernelKind::LowRankFp8,
        ] {
            let a = Matrix::low_rank_noisy(256, 256, 8, 1e-4, &mut rng);
            let b = Matrix::low_rank_noisy(256, 256, 8, 1e-4, &mut rng);
            let resp = svc
                .gemm_blocking(GemmRequest::new(a, b).with_kernel(kind))
                .unwrap();
            out.push(resp.c);
        }
        out
    };
    let off = run(false);
    let on = run(true);
    for (i, (a, b)) in off.iter().zip(&on).enumerate() {
        assert_eq!(a.data(), b.data(), "request {i}: tracing changed bits");
    }
}

#[test]
fn disabled_telemetry_hot_path_is_allocation_free() {
    let registry = MetricsRegistry::new();
    let counter = registry.counter("steady.counter");
    let hist = registry.histogram("steady.hist");
    // Warmup: intern both names, touch this thread's stripe ordinal, and
    // exercise one disabled span site.
    registry.count("steady.counter", 1);
    registry.observe("steady.hist", 1.0);
    {
        let mut sp = trace_plane::span("warmup");
        sp.attr_u64("i", 0);
    }
    let before = thread_allocs();
    for i in 0..1000u64 {
        counter.inc();
        hist.observe(i as f64 + 1.0);
        // String API steady state: read-lock + hash, no allocation.
        registry.count("steady.counter", 1);
        registry.observe("steady.hist", 2.0);
        // Span sites with no active trace are inert.
        let mut sp = trace_plane::span("steady");
        sp.attr_u64("i", i);
        sp.attr_str("kernel", "dense_f32");
    }
    let after = thread_allocs();
    assert_eq!(
        after - before,
        0,
        "disabled telemetry hot path must not allocate"
    );
    assert_eq!(registry.counters()["steady.counter"], 1001 + 1000);
}
