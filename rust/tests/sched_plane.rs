//! Scheduler-plane integration tests: the unified work-stealing pool +
//! admission control (`[scheduler]`) against the legacy two-pool service.
//!
//! The contract under test: enabling the scheduler changes *when and
//! where* work runs — never *what* it computes. Results are bitwise
//! identical at any worker/steal configuration, overload sheds
//! lowest-priority-first with typed reasons, unmeetable deadlines reject
//! at submit (never after execution), tenants dequeue fairly, and drain
//! completes in-flight work while refusing new submits.

use std::time::Duration;

use lowrank_gemm::config::schema::SchedulerSettings;
use lowrank_gemm::coordinator::{GemmRequest, GemmService, Priority, ServiceConfig};
use lowrank_gemm::error::{Error, RejectReason};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::sched::{Pop, QueueMode, SubmitQueue};

fn sched_cfg(workers: usize, steal: bool) -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        scheduler: SchedulerSettings {
            enabled: true,
            workers,
            steal,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn rand_req(n: usize, seed: u64) -> GemmRequest {
    let mut rng = Pcg64::seeded(seed);
    GemmRequest::new(
        Matrix::gaussian(n, n, &mut rng),
        Matrix::gaussian(n, n, &mut rng),
    )
    .with_kernel(KernelKind::DenseF32)
}

/// Run the reference workload — one shard-sized GEMM plus two small ones,
/// submitted concurrently — and return the result matrices in submit order.
fn run_workload(svc: &GemmService) -> Vec<Matrix> {
    let rxs: Vec<_> = [(768usize, 1u64), (96, 2), (96, 3)]
        .iter()
        .map(|&(n, seed)| svc.submit(rand_req(n, seed)).unwrap())
        .collect();
    rxs.into_iter()
        .map(|rx| rx.recv().unwrap().unwrap().c)
        .collect()
}

fn assert_bitwise_eq(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape");
    let same = a
        .data()
        .iter()
        .zip(b.data())
        .all(|(x, y)| x.to_bits() == y.to_bits());
    assert!(same, "{what}: result bits differ");
}

#[test]
fn sched_results_bitwise_identical_to_legacy() {
    // The acceptance bar: every (workers, steal) configuration of the
    // unified scheduler reproduces the two-pool seed bit-for-bit — tile
    // claim order and steal activity must never reach the result bits.
    let legacy = run_workload(
        &GemmService::start(ServiceConfig {
            workers: 2,
            ..Default::default()
        })
        .unwrap(),
    );
    for (workers, steal) in [(1, true), (2, true), (4, true), (2, false)] {
        let got = run_workload(&GemmService::start(sched_cfg(workers, steal)).unwrap());
        for (i, (l, g)) in legacy.iter().zip(&got).enumerate() {
            assert_bitwise_eq(l, g, &format!("workers={workers} steal={steal} req {i}"));
        }
    }
}

#[test]
fn lone_large_gemm_fans_out_via_stealing() {
    // One big request on an otherwise idle 4-worker pool: its dispatch job
    // lands on one worker, that worker's shard helpers go onto its own
    // deque, and the idle siblings can only reach them by stealing — so
    // the steal counter must move.
    let svc = GemmService::start(sched_cfg(4, true)).unwrap();
    let req = rand_req(768, 11);
    let exact_bits: Vec<u32> = svc
        .execute_inline(&rand_req(768, 11))
        .unwrap()
        .c
        .data()
        .iter()
        .map(|x| x.to_bits())
        .collect();
    let resp = svc.gemm_blocking(req).unwrap();
    assert!(
        resp.c
            .data()
            .iter()
            .zip(&exact_bits)
            .all(|(x, b)| x.to_bits() == *b),
        "fanned-out result must match inline execution bit-for-bit"
    );
    let steals = svc
        .metrics()
        .counters()
        .get("sched.steal")
        .copied()
        .unwrap_or(0);
    assert!(steals >= 1, "idle workers must steal the shard helpers");
}

#[test]
fn overload_sheds_lowest_priority_first() {
    // depth 8 → watermarks: Background 4, Batch 6, Interactive 8. A long
    // batch window (nothing completes during the test) makes the
    // admission sequence below fully deterministic.
    let cfg = ServiceConfig {
        max_batch: 64,
        batch_window: Duration::from_secs(2),
        scheduler: SchedulerSettings {
            enabled: true,
            workers: 2,
            queue_depth: 8,
            ..Default::default()
        },
        ..Default::default()
    };
    let svc = GemmService::start(cfg).unwrap();
    let mut rxs = Vec::new();
    let mut submit = |prio: Priority, seed: u64| {
        svc.submit(rand_req(16, seed).with_priority(prio))
            .map(|rx| rxs.push(rx))
    };

    for i in 0..4 {
        submit(Priority::Background, 100 + i).unwrap();
    }
    // In-flight 4 = the Background watermark: Background sheds first…
    match submit(Priority::Background, 104) {
        Err(Error::Rejected(RejectReason::QueueFull { inflight, depth })) => {
            assert_eq!((inflight, depth), (4, 4));
        }
        other => panic!("expected Background QueueFull, got {other:?}"),
    }
    // …while Batch still admits up to 6…
    submit(Priority::Batch, 105).unwrap();
    submit(Priority::Batch, 106).unwrap();
    assert!(matches!(
        submit(Priority::Batch, 107),
        Err(Error::Rejected(RejectReason::QueueFull { depth: 6, .. }))
    ));
    // …and Interactive up to the full depth.
    submit(Priority::Interactive, 108).unwrap();
    submit(Priority::Interactive, 109).unwrap();
    assert!(matches!(
        submit(Priority::Interactive, 110),
        Err(Error::Rejected(RejectReason::QueueFull { depth: 8, .. }))
    ));

    let stats = svc.stats();
    assert_eq!(stats.submitted, 8);
    assert_eq!(stats.rejected, 3);
    assert_eq!(
        stats.metrics.counters.get("sched.shed").copied().unwrap_or(0),
        3,
        "every admission rejection must count as a shed"
    );
}

#[test]
fn unmeetable_deadline_rejected_at_submit() {
    let svc = GemmService::start(sched_cfg(2, true)).unwrap();
    // 1 ns can never cover the routed cost estimate of a 256-class GEMM:
    // rejected before any queue or pool time is spent.
    let err = svc
        .submit(rand_req(256, 21).with_deadline(Duration::from_nanos(1)))
        .unwrap_err();
    match err {
        Error::Rejected(RejectReason::DeadlineUnmeetable {
            estimated_us,
            deadline_us,
        }) => {
            assert!(estimated_us >= deadline_us);
            assert_eq!(deadline_us, 0); // 1 ns truncates to 0 µs
        }
        other => panic!("expected DeadlineUnmeetable, got {other:?}"),
    }
    assert_eq!(svc.stats().completed, 0, "no work may run for a shed request");

    // A generous deadline admits and completes normally.
    let resp = svc
        .gemm_blocking(rand_req(64, 22).with_deadline(Duration::from_secs(60)))
        .unwrap();
    assert_eq!(resp.c.shape(), (64, 64));
    assert_eq!(svc.stats().completed, 1);
}

#[test]
fn tenant_quota_enforced_per_tenant() {
    let cfg = ServiceConfig {
        max_batch: 64,
        batch_window: Duration::from_secs(2), // hold in-flight
        scheduler: SchedulerSettings {
            enabled: true,
            workers: 2,
            tenant_quota: 2,
            ..Default::default()
        },
        ..Default::default()
    };
    let svc = GemmService::start(cfg).unwrap();
    let mut rxs = Vec::new();
    rxs.push(svc.submit(rand_req(16, 31).with_tenant(7)).unwrap());
    rxs.push(svc.submit(rand_req(16, 32).with_tenant(7)).unwrap());
    match svc.submit(rand_req(16, 33).with_tenant(7)) {
        Err(Error::Rejected(RejectReason::TenantQuotaExceeded {
            tenant,
            inflight,
            quota,
        })) => assert_eq!((tenant, inflight, quota), (7, 2, 2)),
        other => panic!("expected TenantQuotaExceeded, got {other:?}"),
    }
    // Other tenants — and anonymous traffic — are unaffected.
    rxs.push(svc.submit(rand_req(16, 34).with_tenant(8)).unwrap());
    rxs.push(svc.submit(rand_req(16, 35)).unwrap());
}

#[test]
fn fair_queue_interleaves_tenants_under_flood() {
    // A 10:1 flood: tenant 1 enqueues ten requests before tenant 2's two.
    // Round-robin dequeue within the priority lane must interleave tenant
    // 2 near the front instead of burying it behind the flood.
    let q: SubmitQueue<(u64, usize)> = SubmitQueue::new(QueueMode::Fair);
    for i in 0..10 {
        q.push((1, i), Priority::Batch.index(), Some(1)).unwrap();
    }
    for i in 0..2 {
        q.push((2, i), Priority::Batch.index(), Some(2)).unwrap();
    }
    let mut order = Vec::new();
    for _ in 0..12 {
        match q.pop_deadline(None) {
            Pop::Item((tenant, _)) => order.push(tenant),
            other => panic!("expected item, got {other:?}"),
        }
    }
    let first_four: Vec<u64> = order.iter().take(4).copied().collect();
    assert_eq!(
        first_four,
        vec![1, 2, 1, 2],
        "tenant 2 must dequeue round-robin, not behind the flood: {order:?}"
    );
}

#[test]
fn drain_completes_inflight_then_rejects_new() {
    let svc = GemmService::start(sched_cfg(2, true)).unwrap();
    let rxs: Vec<_> = (0..4)
        .map(|i| svc.submit(rand_req(32, 41 + i)).unwrap())
        .collect();
    svc.drain();
    assert!(matches!(
        svc.submit(rand_req(32, 50)),
        Err(Error::Rejected(RejectReason::Draining))
    ));
    // Everything admitted before the drain completed normally.
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    assert_eq!(svc.stats().completed, 4);
}

#[test]
fn default_config_registers_no_sched_metrics() {
    // `[scheduler]` unset must be invisible: same metric names as the
    // two-pool seed, nothing `sched.*` registered.
    let svc = GemmService::start(ServiceConfig::default()).unwrap();
    svc.gemm_blocking(rand_req(32, 61)).unwrap();
    let snapshot = svc.stats().metrics;
    assert!(
        !snapshot.counters.keys().any(|k| k.starts_with("sched.")),
        "legacy config leaked sched counters: {:?}",
        snapshot.counters.keys().collect::<Vec<_>>()
    );
    assert!(
        !snapshot.histograms.keys().any(|k| k.starts_with("sched.")),
        "legacy config leaked sched histograms"
    );
    // And rejections still render the historical wording.
    let err = Error::Rejected(RejectReason::QueueFull {
        inflight: 2,
        depth: 2,
    });
    assert_eq!(
        err.to_string(),
        "service error: queue full (2 in flight ≥ depth 2)"
    );
}
