//! Packed-operand plane equivalence suite (PR 5).
//!
//! The packed hot path ([`lowrank_gemm::linalg::pack`]) is a pure
//! re-layout: every packed kernel must reproduce its unpacked
//! counterpart's bits exactly — dense, fused-FP8 and factor-chain, across
//! odd shapes, 1×N / N×1 edges, shard worker counts and pre-packed cache
//! entries. Plus the arena-reuse contract: after warmup, the recycling
//! hot loop performs **zero** heap allocations, asserted through a
//! counting global-allocator shim (per-thread counters, so concurrently
//! running tests in this binary don't perturb each other).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;

use lowrank_gemm::cache::{ContentCache, Fingerprint};
use lowrank_gemm::config::CacheSettings;
use lowrank_gemm::coordinator::{GemmRequest, GemmService, ServiceConfig};
use lowrank_gemm::fp8::{quantized_matmul, quantized_matmul_fused, Fp8Format, StorageFormat};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::pack::{self, PackedB};
use lowrank_gemm::linalg::{
    gemm_blocked, gemm_blocked_unpacked, kernel_params, Matrix, Pcg64,
};
use lowrank_gemm::lowrank::{factorize, lowrank_matmul, LowRankConfig, RankStrategy};
use lowrank_gemm::shard::{ShardExecutor, ShardPlan, TileGrid};

// ---------------------------------------------------------------------------
// Counting allocator shim: per-thread allocation counters.
// ---------------------------------------------------------------------------

std::thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates everything to `System`; the counter update is a plain
// thread-local store with no allocation of its own (const-initialized TLS).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// Bitwise equivalence: dense
// ---------------------------------------------------------------------------

#[test]
fn packed_dense_bitwise_across_odd_shapes() {
    let mut rng = Pcg64::seeded(501);
    // Odd shapes off every blocking multiple, plus degenerate edges:
    // single row (scalar-row zone only), single column (remainder-column
    // path only), k = 1.
    for (m, k, n) in [
        (97, 131, 89),
        (130, 257, 259),
        (300, 96, 520),
        (255, 255, 255),
        (1, 2000, 300),  // single output row above the cutover: scalar zone only
        (300, 2000, 1),  // single output column: remainder-column path only
        (800, 1, 700),   // k = 1: one-step panels
        (96, 96, 96),    // below the naive cutover: both sides go naive
    ] {
        let a = Matrix::gaussian(m, k, &mut rng);
        let b = Matrix::gaussian(k, n, &mut rng);
        let packed = gemm_blocked(&a, &b).unwrap();
        let unpacked = gemm_blocked_unpacked(&a, &b).unwrap();
        assert_eq!(packed.data(), unpacked.data(), "shape ({m},{k},{n})");
    }
}

#[test]
fn sharded_packed_dense_bitwise_across_worker_counts() {
    let mut rng = Pcg64::seeded(502);
    let a = Matrix::gaussian(520, 140, &mut rng);
    let b = Matrix::gaussian(140, 330, &mut rng);
    let monolithic = gemm_blocked_unpacked(&a, &b).unwrap();
    for workers in [1, 2, 3, 8] {
        let ex = ShardExecutor::new(ShardPlan {
            grid: TileGrid::default(),
            workers,
            min_parallel_n: 64,
        });
        let sharded = ex.gemm(&a, &b).unwrap();
        assert_eq!(
            monolithic.data(),
            sharded.data(),
            "workers={workers}: shared-packed tiles must reproduce the \
             monolithic unpacked kernel"
        );
    }
}

// ---------------------------------------------------------------------------
// Bitwise equivalence: fused FP8 decode-into-pack
// ---------------------------------------------------------------------------

#[test]
fn fused_fp8_bitwise_across_formats_and_shapes() {
    let mut rng = Pcg64::seeded(503);
    for fmt in [
        StorageFormat::Fp8(Fp8Format::E4M3),
        StorageFormat::Fp8(Fp8Format::E5M2),
        StorageFormat::F16,
        StorageFormat::Bf16,
    ] {
        for (m, k, n) in [(130, 140, 150), (97, 260, 131), (1, 1200, 600), (600, 1200, 1)] {
            let a = Matrix::gaussian(m, k, &mut rng);
            let b = Matrix::gaussian(k, n, &mut rng);
            let fused = quantized_matmul_fused(&a, &b, fmt);
            let unfused = quantized_matmul(&a, &b, fmt);
            assert_eq!(fused.data(), unfused.data(), "{fmt:?} ({m},{k},{n})");
        }
    }
}

#[test]
fn sharded_fused_fp8_bitwise_across_worker_counts() {
    let mut rng = Pcg64::seeded(504);
    let a = Matrix::gaussian(300, 200, &mut rng);
    let b = Matrix::gaussian(200, 520, &mut rng);
    let fmt = StorageFormat::Fp8(Fp8Format::E4M3);
    let serial = quantized_matmul(&a, &b, fmt);
    for workers in [1, 2, 5] {
        let ex = ShardExecutor::new(ShardPlan {
            grid: TileGrid::default(),
            workers,
            min_parallel_n: 64,
        });
        let fused = ex.quantized_matmul(&a, &b, fmt).unwrap();
        assert_eq!(serial.data(), fused.data(), "workers={workers}");
    }
}

// ---------------------------------------------------------------------------
// Bitwise equivalence: factor chain + pre-packed cache entries
// ---------------------------------------------------------------------------

#[test]
fn factor_chain_bitwise_serial_sharded_and_prepacked() {
    let mut rng = Pcg64::seeded(505);
    // Rank 16 at N=1024 puts the reconstruction product above the shard
    // plane's FLOP gate, so the prepacked panels are consumed on the
    // *sharded* path too (the 640-class chains only exercise serial).
    let a = Matrix::low_rank(1024, 768, 16, &mut rng);
    let b = Matrix::low_rank(768, 1024, 16, &mut rng);
    let cfg = LowRankConfig {
        rank: RankStrategy::Fixed(16),
        storage: StorageFormat::Fp8(Fp8Format::E4M3),
        ..Default::default()
    };
    let fa = factorize(&a, &cfg).unwrap();
    let fb = factorize(&b, &cfg).unwrap();
    let reference = lowrank_matmul(&fa, &fb);
    let p = kernel_params();
    let prepacked = Arc::new(PackedB::pack_quantized(&fb.vt, p.kc, p.nc));
    for workers in [1, 3] {
        let ex = ShardExecutor::new(ShardPlan {
            grid: TileGrid::default(),
            workers,
            min_parallel_n: 64,
        });
        let chain = ex.lowrank_matmul(&fa, &fb).unwrap();
        assert_eq!(reference.data(), chain.data(), "workers={workers}");
        let pre = ex
            .lowrank_matmul_prepacked(&fa, &fb, Some(&prepacked))
            .unwrap();
        assert_eq!(reference.data(), pre.data(), "prepacked workers={workers}");
    }
}

#[test]
fn content_cache_prepacked_hits_are_bitwise_and_counted() {
    // Service-level `[cache] prepack`: hits consume ready-made Vᵀ panels
    // (pack.prepacked_hit metric) and must replay the cold bits exactly.
    let cfg = ServiceConfig {
        cache: CacheSettings {
            enabled: true,
            min_dim: 32,
            prepack: true,
            ..Default::default()
        },
        ..Default::default()
    };
    let svc = GemmService::start(cfg).unwrap();
    let mut rng = Pcg64::seeded(506);
    // Large enough that the reconstruction clears the naive cutover and
    // the panels are consumed, small enough to stay quick.
    let w = Matrix::low_rank_noisy(384, 384, 8, 1e-5, &mut rng);
    let x = Matrix::low_rank_noisy(384, 384, 8, 1e-5, &mut rng);
    let req = || GemmRequest::new(w.clone(), x.clone()).with_kernel(KernelKind::LowRankFp8);
    let r1 = svc.gemm_blocking(req()).unwrap();
    let r2 = svc.gemm_blocking(req()).unwrap();
    assert_eq!(r1.c.data(), r2.c.data(), "prepacked hit must replay cold bits");
    let counters = svc.metrics().counters();
    assert!(
        counters.get("pack.prepacked_hit").copied().unwrap_or(0) >= 1,
        "second request must hit pre-packed entries: {counters:?}"
    );
    assert!(
        counters.get("pack.prepacked_use").copied().unwrap_or(0) >= 1,
        "the chain must actually consume the pre-packed panels: {counters:?}"
    );
}

#[test]
fn direct_store_prepack_roundtrip_matches_fresh_pack() {
    let mut rng = Pcg64::seeded(507);
    let b = Matrix::low_rank(256, 300, 6, &mut rng);
    let cfg = LowRankConfig {
        rank: RankStrategy::Fixed(6),
        storage: StorageFormat::F32,
        ..Default::default()
    };
    let fb = factorize(&b, &cfg).unwrap();
    let store = ContentCache::new(16 << 20, 1).with_prepack(true);
    let fp = Fingerprint::of(&b);
    assert!(store.put(fp, fb.clone()));
    let hit = store.get_cached(fp).unwrap();
    let pb = hit.packed_vt.expect("panels stored");
    let p = kernel_params();
    let fresh = PackedB::pack(&fb.vt_dense(), p.kc, p.nc);
    for pc in (0..pb.k()).step_by(pb.kc()) {
        for jc in (0..pb.n()).step_by(pb.nc()) {
            assert_eq!(pb.panel(pc, jc), fresh.panel(pc, jc), "panel ({pc},{jc})");
        }
    }
}

// ---------------------------------------------------------------------------
// Arena reuse: zero allocations after warmup
// ---------------------------------------------------------------------------

#[test]
fn dense_hot_loop_is_allocation_free_after_warmup() {
    let mut rng = Pcg64::seeded(508);
    // Above the naive cutover so the packed path (A pack + B pack +
    // output checkout) runs end to end.
    let a = Matrix::gaussian(200, 160, &mut rng);
    let b = Matrix::gaussian(160, 192, &mut rng);
    // Warmup: populate this thread's arena with every buffer size the
    // loop needs (the output is recycled back by the caller, as a
    // steady-state serving loop would).
    for _ in 0..3 {
        let c = gemm_blocked(&a, &b).unwrap();
        pack::recycle(c.into_vec());
    }
    let before = thread_allocs();
    for _ in 0..5 {
        let c = gemm_blocked(&a, &b).unwrap();
        pack::recycle(c.into_vec());
    }
    let after = thread_allocs();
    assert_eq!(
        after - before,
        0,
        "warmed-up packed GEMM must not allocate (arena stats: {:?})",
        pack::stats()
    );
}

#[test]
fn factor_chain_is_allocation_free_after_warmup() {
    let mut rng = Pcg64::seeded(509);
    let a = Matrix::low_rank(256, 192, 8, &mut rng);
    let b = Matrix::low_rank(192, 256, 8, &mut rng);
    let cfg = LowRankConfig {
        rank: RankStrategy::Fixed(8),
        storage: StorageFormat::Fp8(Fp8Format::E4M3),
        ..Default::default()
    };
    let fa = factorize(&a, &cfg).unwrap();
    let fb = factorize(&b, &cfg).unwrap();
    // Serial executor (huge gate) with no metrics: the whole chain runs
    // on this thread, so every intermediate rides this thread's arena.
    let ex = ShardExecutor::new(ShardPlan {
        grid: TileGrid::default(),
        workers: 1,
        min_parallel_n: usize::MAX,
    });
    for _ in 0..3 {
        let c = ex.lowrank_matmul(&fa, &fb).unwrap();
        pack::recycle(c.into_vec());
    }
    let before = thread_allocs();
    for _ in 0..5 {
        let c = ex.lowrank_matmul(&fa, &fb).unwrap();
        pack::recycle(c.into_vec());
    }
    let after = thread_allocs();
    assert_eq!(
        after - before,
        0,
        "warmed-up factor chain must not allocate (arena stats: {:?})",
        pack::stats()
    );
}
