//! Randomized property tests over coordinator + numeric invariants.
//!
//! No proptest crate in the offline vendor set, so properties are swept
//! with the house Pcg64 over many random cases; each case prints its seed
//! on failure for replay.

use std::sync::Arc;

use lowrank_gemm::coordinator::{Batcher, BucketKey, GemmRequest, Router, RouterConfig};
use lowrank_gemm::fp8::{dequantize, quantize, StorageFormat};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::gemm::gemm_strided;
use lowrank_gemm::linalg::{gemm_blocked, gemm_naive, Matrix, Pcg64};
use lowrank_gemm::lowrank::{
    eckart_young_error, energy_capture, factorize, lowrank_matmul, FactorCache, LowRankConfig,
    RankStrategy,
};

fn dims(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
    lo + (rng.next_u64() as usize) % (hi - lo + 1)
}

/// Property: blocked GEMM ≡ naive GEMM on arbitrary shapes.
#[test]
fn prop_blocked_gemm_matches_naive() {
    for seed in 0..30u64 {
        let mut rng = Pcg64::seeded(1000 + seed);
        let (m, k, n) = (dims(&mut rng, 1, 60), dims(&mut rng, 1, 60), dims(&mut rng, 1, 60));
        let a = Matrix::gaussian(m, k, &mut rng);
        let b = Matrix::gaussian(k, n, &mut rng);
        let c1 = gemm_naive(&a, &b).unwrap();
        let c2 = gemm_blocked(&a, &b).unwrap();
        let err = c1.rel_frobenius_distance(&c2);
        assert!(err < 1e-5, "seed {seed} ({m}x{k}x{n}): err {err}");
    }
}

/// Property: `gemm_strided` on a random sub-block of a random matmul must
/// bit-match the corresponding slice of the `gemm_blocked` output. Shapes
/// are kept under the blocked kernel's naive cutover, where both paths
/// accumulate per element over ascending `t` with the same zero-skip —
/// identical order ⇒ identical bits.
#[test]
fn prop_gemm_strided_bitmatches_blocked_subblocks() {
    for seed in 0..25u64 {
        let mut rng = Pcg64::seeded(9000 + seed);
        let (m, k, n) = (dims(&mut rng, 2, 50), dims(&mut rng, 2, 50), dims(&mut rng, 2, 50));
        let a = Matrix::gaussian(m, k, &mut rng);
        let b = Matrix::gaussian(k, n, &mut rng);
        let full = gemm_blocked(&a, &b).unwrap();

        let h = dims(&mut rng, 1, m);
        let w = dims(&mut rng, 1, n);
        let r0 = dims(&mut rng, 0, m - h);
        let c0 = dims(&mut rng, 0, n - w);

        let mut out = vec![0.0f32; h * w];
        gemm_strided(
            &a.data()[r0 * k..],
            k,
            &b.data()[c0..],
            n,
            &mut out,
            w,
            h,
            w,
            k,
        );
        for i in 0..h {
            for j in 0..w {
                let got = out[i * w + j];
                let want = full[(r0 + i, c0 + j)];
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "seed {seed} ({m}x{k}x{n}) block {h}x{w}@({r0},{c0}) at ({i},{j}): \
                     {got} vs {want}"
                );
            }
        }
    }
}

/// Property: quantize→dequantize error ordering F32 ≤ F16 ≤ FP8 in
/// Frobenius norm, for any input distribution.
#[test]
fn prop_storage_precision_error_ordering() {
    for seed in 0..20u64 {
        let mut rng = Pcg64::seeded(2000 + seed);
        let scale = (2.0f32).powi((rng.next_u64() % 24) as i32 - 12);
        let m = Matrix::uniform(24, 24, -scale, scale, &mut rng);
        let err = |f: StorageFormat| dequantize(&quantize(&m, f)).rel_frobenius_distance(&m);
        let e32 = err(StorageFormat::F32);
        let e16 = err(StorageFormat::F16);
        let e8 = err(StorageFormat::Fp8(lowrank_gemm::fp8::Fp8Format::E4M3));
        assert!(e32 <= e16 + 1e-7, "seed {seed}: f32 {e32} vs f16 {e16}");
        assert!(e16 <= e8 + 1e-7, "seed {seed}: f16 {e16} vs fp8 {e8}");
    }
}

/// Property: the factor-chain product error is bounded by the sum of the
/// two truncation errors plus quantization noise (triangle-style bound).
#[test]
fn prop_chain_error_bounded_by_operand_truncations() {
    for seed in 0..15u64 {
        let mut rng = Pcg64::seeded(3000 + seed);
        let n = dims(&mut rng, 24, 64);
        let r = dims(&mut rng, 2, 8);
        let a = Matrix::low_rank_noisy(n, n, r, 1e-3, &mut rng);
        let b = Matrix::low_rank_noisy(n, n, r, 1e-3, &mut rng);
        let cfg = LowRankConfig {
            rank: RankStrategy::Fixed(r),
            storage: StorageFormat::F32,
            ..Default::default()
        };
        let fa = factorize(&a, &cfg).unwrap();
        let fb = factorize(&b, &cfg).unwrap();
        let ea = fa.measured_error(&a);
        let eb = fb.measured_error(&b);
        let ec = lowrank_matmul(&fa, &fb).rel_frobenius_distance(&a.matmul(&b));
        // Condition-number slack of 4 over the naive triangle bound.
        assert!(
            ec <= 4.0 * (ea + eb) + 5e-3,
            "seed {seed}: chain {ec} vs operands {ea}+{eb}"
        );
    }
}

/// Property: energy capture is monotone in rank and hits 1 at full rank;
/// Eckart–Young error is monotone decreasing.
#[test]
fn prop_energy_and_eckart_young_monotone() {
    for seed in 0..20u64 {
        let mut rng = Pcg64::seeded(4000 + seed);
        let k = dims(&mut rng, 3, 40);
        let mut sv: Vec<f32> = (0..k).map(|_| (rng.next_u64() % 1000) as f32 / 100.0 + 0.01).collect();
        sv.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mut prev_energy = 0.0f32;
        let mut prev_err = f32::INFINITY;
        for r in 1..=k {
            let e = energy_capture(&sv, r);
            let err = eckart_young_error(&sv, r);
            assert!(e >= prev_energy - 1e-6, "seed {seed} r={r}");
            assert!(err <= prev_err + 1e-6, "seed {seed} r={r}");
            prev_energy = e;
            prev_err = err;
        }
        assert!((prev_energy - 1.0).abs() < 1e-5);
        assert!(prev_err.abs() < 1e-4);
    }
}

/// Property: the router never picks a low-rank kernel when the tolerance
/// is tighter than the predicted truncation error.
#[test]
fn prop_router_respects_tolerance() {
    let router = Router::new(RouterConfig::default(), Arc::new(FactorCache::new(1 << 20)));
    for seed in 0..25u64 {
        let mut rng = Pcg64::seeded(5000 + seed);
        let n = 32 << (rng.next_u64() % 6); // 32..1024
        let a = Matrix::zeros(n, n);
        let b = Matrix::zeros(n, n);
        let req = GemmRequest::new(a, b).with_tolerance(1e-6);
        let plan = router.route(&req);
        assert!(
            !plan.choice.kind.is_lowrank(),
            "seed {seed} n={n}: picked {:?} at tol 1e-6",
            plan.choice.kind
        );
        assert!(plan.choice.predicted_error <= 1e-5, "seed {seed}");
    }
}

/// Property: batcher conservation — every pushed item comes back exactly
/// once across full-batch flushes, expiry flushes and the final drain.
#[test]
fn prop_batcher_conserves_items() {
    use std::time::{Duration, Instant};
    for seed in 0..20u64 {
        let mut rng = Pcg64::seeded(6000 + seed);
        let max_batch = 1 + (rng.next_u64() % 6) as usize;
        let mut batcher: Batcher<u64> = Batcher::new(max_batch, Duration::from_micros(50));
        let t0 = Instant::now();
        let total = 50 + (rng.next_u64() % 100) as usize;
        let mut seen = Vec::new();
        for i in 0..total {
            let kind = if rng.next_u64() % 2 == 0 {
                KernelKind::DenseF32
            } else {
                KernelKind::LowRankFp8
            };
            let n = 16 << (rng.next_u64() % 8);
            let key = BucketKey::of(kind, n, n, n);
            let t = t0 + Duration::from_micros(i as u64 * 7);
            if let Some((_, items)) = batcher.push(key, i as u64, t) {
                assert!(items.len() == max_batch, "full flush wrong size");
                seen.extend(items);
            }
            for (_, items) in batcher.flush_expired(t) {
                seen.extend(items);
            }
        }
        for (_, items) in batcher.flush_all() {
            seen.extend(items);
        }
        seen.sort_unstable();
        let expect: Vec<u64> = (0..total as u64).collect();
        assert_eq!(seen, expect, "seed {seed}: item loss or duplication");
    }
}

/// Property: factor cache respects its byte budget under random workloads
/// and never loses the most recently used entry.
#[test]
fn prop_cache_budget_and_lru() {
    for seed in 0..15u64 {
        let mut rng = Pcg64::seeded(7000 + seed);
        let budget = 40_000usize;
        let cache = FactorCache::new(budget);
        let cfg = LowRankConfig {
            rank: RankStrategy::Fixed(4),
            storage: StorageFormat::F32,
            ..Default::default()
        };
        let mut last = 0u64;
        for i in 0..40u64 {
            let n = dims(&mut rng, 16, 48);
            let m = Matrix::low_rank(n, n, 4, &mut rng);
            let f = factorize(&m, &cfg).unwrap();
            if cache.put(i, f) {
                last = i;
            }
            let stats = cache.stats();
            assert!(
                stats.resident_bytes <= budget as u64,
                "seed {seed}: over budget"
            );
            // The entry we just inserted must be resident.
            assert!(cache.contains(last), "seed {seed}: MRU evicted");
        }
    }
}

/// Property: Lanczos, rSVD and exact SVD agree on the leading singular
/// value for arbitrary (well-scaled) inputs.
#[test]
fn prop_decomposition_methods_agree_on_sigma1() {
    use lowrank_gemm::linalg::{jacobi_svd, lanczos_svd, rsvd, RsvdOptions};
    for seed in 0..12u64 {
        let mut rng = Pcg64::seeded(8000 + seed);
        let (m, n) = (dims(&mut rng, 12, 40), dims(&mut rng, 12, 40));
        let a = Matrix::gaussian(m, n, &mut rng);
        let exact = jacobi_svd(&a).unwrap().s[0];
        let rs = rsvd(&a, 6.min(m.min(n)), &RsvdOptions::default()).unwrap().s[0];
        let lz = lanczos_svd(&a, 6.min(m.min(n)), 6, 42).unwrap().s[0];
        assert!((rs - exact).abs() / exact < 0.02, "seed {seed}: rsvd {rs} vs {exact}");
        assert!((lz - exact).abs() / exact < 0.02, "seed {seed}: lanczos {lz} vs {exact}");
    }
}
