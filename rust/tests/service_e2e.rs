//! End-to-end serving tests: GemmService over the full stack
//! (router → batcher → workers → XLA artifacts / CPU substrate).

use std::time::Duration;

use lowrank_gemm::coordinator::{BackendKind, GemmRequest, GemmService, ServiceConfig};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::lowrank::RankStrategy;
use lowrank_gemm::trace;

fn with_artifacts() -> Option<ServiceConfig> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping service e2e test: run `make artifacts` first");
        return None;
    }
    Some(ServiceConfig {
        artifacts_dir: Some("artifacts".into()),
        workers: 2,
        max_batch: 4,
        batch_window: Duration::from_micros(150),
        ..Default::default()
    })
}

#[test]
fn shipped_config_file_parses_and_boots() {
    // The example config in the repo root must stay in sync with the
    // schema — and a service must boot from it (CPU-only to keep the
    // test independent of artifacts).
    let text = std::fs::read_to_string("lowrank-gemm.toml").expect("shipped config");
    let mut app = lowrank_gemm::config::AppConfig::from_toml(&text).expect("parse");
    assert_eq!(app.device, "rtx4090");
    assert_eq!(
        app.rank_strategy,
        lowrank_gemm::lowrank::RankStrategy::EnergyFraction(0.99)
    );
    assert_eq!(app.service.factor_cache_bytes, 256 << 20);
    app.use_xla = false;
    let cfg = ServiceConfig::from_app(&app).expect("service config");
    let svc = GemmService::start(cfg).expect("boot");
    let mut rng = Pcg64::seeded(31);
    let resp = svc
        .gemm_blocking(GemmRequest::new(
            Matrix::gaussian(24, 24, &mut rng),
            Matrix::gaussian(24, 24, &mut rng),
        ))
        .unwrap();
    assert_eq!(resp.c.shape(), (24, 24));
}

#[test]
fn dense_requests_on_lattice_run_via_xla() {
    let Some(cfg) = with_artifacts() else { return };
    let svc = GemmService::start(cfg).unwrap();
    let mut rng = Pcg64::seeded(21);
    let a = Matrix::gaussian(128, 128, &mut rng);
    let b = Matrix::gaussian(128, 128, &mut rng);
    let exact = a.matmul(&b);

    let resp = svc
        .gemm_blocking(GemmRequest::new(a, b).with_kernel(KernelKind::DenseF32))
        .unwrap();
    assert_eq!(resp.backend, BackendKind::Xla, "lattice hit must use XLA");
    assert!(resp.c.rel_frobenius_distance(&exact) < 1e-5);
}

#[test]
fn off_lattice_requests_fall_back_to_cpu() {
    let Some(cfg) = with_artifacts() else { return };
    let svc = GemmService::start(cfg).unwrap();
    let mut rng = Pcg64::seeded(22);
    // 100 is not on the {64,128,256} lattice.
    let a = Matrix::gaussian(100, 100, &mut rng);
    let b = Matrix::gaussian(100, 100, &mut rng);
    let exact = a.matmul(&b);

    let resp = svc
        .gemm_blocking(GemmRequest::new(a, b).with_kernel(KernelKind::DenseF32))
        .unwrap();
    assert_eq!(resp.backend, BackendKind::CpuSubstrate);
    assert!(resp.c.rel_frobenius_distance(&exact) < 1e-5);
}

#[test]
fn lowrank_xla_path_with_preloaded_factors() {
    let Some(mut cfg) = with_artifacts() else { return };
    // Fixed rank 16 lines the request up with the artifact lattice;
    // f32 factor storage isolates the truncation error from fp8 noise.
    cfg.router.rank_strategy = RankStrategy::Fixed(16);
    cfg.router.storage = lowrank_gemm::fp8::StorageFormat::F32;
    let svc = GemmService::start(cfg).unwrap();
    let mut rng = Pcg64::seeded(23);
    let n = 128;
    let a = Matrix::low_rank_noisy(n, n, 8, 1e-5, &mut rng);
    let b = Matrix::low_rank_noisy(n, n, 8, 1e-5, &mut rng);
    svc.preload_factor(1, &a).unwrap();
    svc.preload_factor(2, &b).unwrap();

    let req = GemmRequest::new(a.clone(), b.clone())
        .with_ids(Some(1), Some(2))
        .with_kernel(KernelKind::LowRankAuto);
    let resp = svc.gemm_blocking(req).unwrap();
    assert_eq!(resp.backend, BackendKind::Xla, "equal-rank lattice hit must use XLA");
    assert_eq!(resp.rank, 16);
    let exact = a.matmul(&b);
    let err = resp.c.rel_frobenius_distance(&exact);
    assert!(err < 0.02, "err {err}");
    assert!(svc.stats().cache.hits >= 2);
}

#[test]
fn transformer_trace_replay_end_to_end() {
    // The examples/transformer_serving driver in miniature: weights
    // preloaded, activations replayed, everything correct and counted.
    let Some(cfg) = with_artifacts() else { return };
    let svc = GemmService::start(cfg).unwrap();
    let mut rng = Pcg64::seeded(24);
    let d = 64;
    let shapes = trace::transformer_layer_trace(d, d, 2 * d, 0);

    let mut weights = Vec::new();
    for shape in &shapes {
        let w = Matrix::low_rank_noisy(shape.k, shape.n, 6, 1e-4, &mut rng);
        let id = shape.weight_id.unwrap();
        svc.preload_factor(id, &w).unwrap();
        weights.push((id, w));
    }

    let mut rxs = Vec::new();
    let mut exacts = Vec::new();
    for step in 0..12 {
        let (id, w) = &weights[step % weights.len()];
        let x = Matrix::gaussian(d, w.rows(), &mut rng);
        exacts.push(x.matmul(w));
        rxs.push(
            svc.submit(GemmRequest::new(x, w.clone()).with_ids(None, Some(*id)))
                .unwrap(),
        );
    }
    for (rx, exact) in rxs.into_iter().zip(exacts) {
        let resp = rx.recv().unwrap().unwrap();
        let err = resp.c.rel_frobenius_distance(&exact);
        assert!(err < 0.05, "replay err {err}");
    }
    let stats = svc.stats();
    assert_eq!(stats.completed, 12);
    assert_eq!(stats.rejected, 0);

    // Latency histograms were populated.
    let summaries = svc.metrics().histogram_summaries();
    assert!(summaries.contains_key("gemm.exec_us"));
    assert!(summaries["gemm.exec_us"].count >= 12);
}

#[test]
fn mixed_kernel_burst_batches_by_bucket() {
    let Some(mut cfg) = with_artifacts() else { return };
    cfg.max_batch = 3;
    cfg.batch_window = Duration::from_millis(5);
    let svc = GemmService::start(cfg).unwrap();
    let mut rng = Pcg64::seeded(25);

    let mut rxs = Vec::new();
    for i in 0..9 {
        let n = if i % 2 == 0 { 64 } else { 128 };
        let a = Matrix::gaussian(n, n, &mut rng);
        let b = Matrix::gaussian(n, n, &mut rng);
        rxs.push(
            svc.submit(GemmRequest::new(a, b).with_kernel(KernelKind::DenseF32))
                .unwrap(),
        );
    }
    let mut batched = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        if resp.batch_size > 1 {
            batched += 1;
        }
    }
    assert!(batched >= 4, "expected bucket batching, got {batched} batched responses");
}

#[test]
fn mixed_factored_dense_serving_path() {
    // The x·W serving case: weight factored + cached, activation dense.
    // Must (a) route low-rank warm, (b) never factorize the activation,
    // (c) stay in the error band.
    let Some(mut cfg) = with_artifacts() else { return };
    cfg.router.rank_strategy = RankStrategy::Fixed(8);
    cfg.router.storage = lowrank_gemm::fp8::StorageFormat::F32;
    let svc = GemmService::start(cfg).unwrap();
    let mut rng = Pcg64::seeded(27);
    let (t, k, n) = (64usize, 96usize, 80usize);
    let w = Matrix::low_rank_noisy(k, n, 6, 1e-5, &mut rng);
    svc.preload_factor(5, &w).unwrap();

    for _ in 0..3 {
        let x = Matrix::gaussian(t, k, &mut rng);
        let exact = x.matmul(&w);
        let req = GemmRequest::new(x, w.clone())
            .with_ids(None, Some(5))
            .with_kernel(KernelKind::LowRankAuto);
        let plan = svc.plan(&req);
        assert!(plan.factors_cached, "one-sided cache must count as warm");
        let resp = svc.gemm_blocking(req).unwrap();
        assert_eq!(resp.rank, 8); // service strategy Fixed(8)
        assert!(resp.c.rel_frobenius_distance(&exact) < 0.02);
    }
    let stats = svc.stats();
    assert!(stats.cache.hits >= 3);
    assert_eq!(stats.cache.misses, 0, "activation must never be factorized");
}

#[test]
fn per_request_tolerance_steers_kernel_choice() {
    let Some(cfg) = with_artifacts() else { return };
    let svc = GemmService::start(cfg).unwrap();
    let mut rng = Pcg64::seeded(26);
    let a = Matrix::gaussian(256, 256, &mut rng);
    let b = Matrix::gaussian(256, 256, &mut rng);

    // Tight tolerance: must land on the exact kernel.
    let strict = svc
        .plan(&GemmRequest::new(a.clone(), b.clone()).with_tolerance(1e-6));
    assert_eq!(strict.choice.kind, KernelKind::DenseF32);

    // Loose tolerance at this (small) size: still dense (crossover is far
    // away), but allowed to pick a reduced-precision kernel.
    let loose = svc.plan(&GemmRequest::new(a, b).with_tolerance(0.5));
    assert!(!loose.choice.kind.is_lowrank());
}
