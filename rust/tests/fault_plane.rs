//! Fault-plane integration tests: panic containment, the degradation
//! ladder + circuit breaker, degraded boot, and the disabled-plane
//! identity contract (`[fault]`).
//!
//! The contract under test: with the plane disabled (the default) the
//! service is bitwise-identical to the seed — same results, same metric
//! namespace. With the plane up and deterministic injection armed, no
//! panic escapes a job boundary, every submitted request resolves (ok or
//! typed error, never a hung waiter), failing kernel families walk the
//! degradation ladder under breaker control, and a corrupt persistence
//! table quarantines at boot instead of failing start.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use lowrank_gemm::config::schema::{AutotuneSettings, FaultInjectSettings, FaultSettings};
use lowrank_gemm::coordinator::{GemmRequest, GemmService, ServiceConfig};
use lowrank_gemm::error::Error;
use lowrank_gemm::fault::{BreakerState, DegradeReason, FaultPlane};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::metrics::MetricsRegistry;
use lowrank_gemm::shard::{ShardExecutor, ShardPlan};

fn fault_cfg(fault: FaultSettings) -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        fault,
        ..Default::default()
    }
}

fn forced_req(n: usize, seed: u64, kind: KernelKind) -> GemmRequest {
    let mut rng = Pcg64::seeded(seed);
    GemmRequest::new(
        Matrix::gaussian(n, n, &mut rng),
        Matrix::gaussian(n, n, &mut rng),
    )
    .with_kernel(kind)
}

fn assert_bitwise_eq(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape");
    let same = a
        .data()
        .iter()
        .zip(b.data())
        .all(|(x, y)| x.to_bits() == y.to_bits());
    assert!(same, "{what}: result bits differ");
}

fn counter(svc: &GemmService, name: &str) -> u64 {
    svc.metrics().counters().get(name).copied().unwrap_or(0)
}

/// The workload both halves of the tile-panic test replay: alternating
/// shard-sized (tiled, injectable) and small (monolithic, fault-free)
/// GEMMs, all forced to the dense-f32 ladder floor so a tile panic has
/// no fallback and must surface as a typed error.
fn tile_workload() -> Vec<GemmRequest> {
    (0..12)
        .map(|i| {
            let n = if i % 2 == 0 { 768 } else { 96 };
            forced_req(n, 100 + i as u64, KernelKind::DenseF32)
        })
        .collect()
}

#[test]
fn injected_tile_panics_are_contained_and_survivors_bitwise_correct() {
    // Baseline: the same workload on a fault-free default service.
    let clean = GemmService::start(ServiceConfig {
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    let baseline: Vec<Matrix> = tile_workload()
        .into_iter()
        .map(|r| clean.gemm_blocking(r).unwrap().c)
        .collect();
    drop(clean);

    let svc = GemmService::start(fault_cfg(FaultSettings {
        enabled: true,
        inject: FaultInjectSettings {
            seed: 5,
            panic_tile: 0.25,
            ..Default::default()
        },
        ..Default::default()
    }))
    .unwrap();

    let mut ok = 0usize;
    let mut panicked = 0usize;
    for (i, req) in tile_workload().into_iter().enumerate() {
        // Sequential blocking submits: every request must *resolve* —
        // gemm_blocking returning at all is the no-hung-waiter assertion.
        match svc.gemm_blocking(req) {
            Ok(resp) => {
                ok += 1;
                assert_bitwise_eq(&resp.c, &baseline[i], &format!("request {i}"));
            }
            Err(Error::KernelPanicked(_)) => panicked += 1,
            Err(e) => panic!("request {i}: unexpected error kind: {e}"),
        }
    }
    assert_eq!(ok + panicked, 12, "every request resolves");
    // The small monolithic GEMMs never shard, so they cannot draw a tile
    // fault: at least those six must have served, bitwise-correct.
    assert!(ok >= 6, "un-tiled requests must survive (got {ok} ok)");
    assert!(
        counter(&svc, "fault.panic.tile") >= 1,
        "seeded plan must fire at least one tile panic"
    );
    assert!(counter(&svc, "fault.injected") >= 1);
    // One request may lose several tiles, so the tile-panic count is a
    // lower bound on nothing but itself; it must at least cover the
    // per-request failures observed above.
    assert!(counter(&svc, "fault.panic.tile") >= panicked as u64);

    // The pool survived every contained panic: a fresh request serves.
    let resp = svc
        .gemm_blocking(forced_req(96, 999, KernelKind::DenseF32))
        .unwrap();
    assert_eq!(resp.kernel, KernelKind::DenseF32);
}

#[test]
fn breaker_trips_walks_ladder_and_recovers_half_open() {
    // error_requests_under=3 makes service ids 1 and 2 (ids start at 1)
    // fail their first attempt on lowrank_fp8 — exactly the two failures
    // the window-2/threshold-2 breaker needs to trip. cooldown=2 then
    // makes request 4's route consult the admitted half-open probe.
    let svc = GemmService::start(fault_cfg(FaultSettings {
        enabled: true,
        breaker_window: 2,
        breaker_threshold: 2,
        breaker_cooldown: 2,
        inject: FaultInjectSettings {
            error_kernel: "lowrank_fp8".into(),
            error_requests_under: 3,
            ..Default::default()
        },
        ..Default::default()
    }))
    .unwrap();

    let run = |seed: u64| {
        svc.gemm_blocking(forced_req(96, seed, KernelKind::LowRankFp8))
            .unwrap()
    };

    // Requests 1 and 2: injected kernel error, one retry down the ladder.
    for seed in [1, 2] {
        let resp = run(seed);
        assert_eq!(resp.kernel, KernelKind::DenseF32, "served on the fallback");
        assert_eq!(
            resp.degraded,
            Some(DegradeReason::RetryAfterError {
                from: KernelKind::LowRankFp8
            })
        );
    }
    let plane = svc.fault().expect("plane enabled");
    assert_eq!(plane.breaker_state(KernelKind::LowRankFp8), BreakerState::Open);
    assert_eq!(counter(&svc, "fault.breaker.trip"), 1);

    // Request 3: breaker-open reroute at route time (first cooldown
    // denial) — no failed attempt at all, straight to the floor.
    let resp = run(3);
    assert_eq!(resp.kernel, KernelKind::DenseF32);
    assert_eq!(
        resp.degraded,
        Some(DegradeReason::BreakerOpen {
            from: KernelKind::LowRankFp8
        })
    );

    // Request 4: the second denial completes the cooldown — this request
    // IS the half-open probe, serves on lowrank_fp8 (injection is off
    // past id 3), and its success recovers the breaker.
    let resp = run(4);
    assert_eq!(resp.kernel, KernelKind::LowRankFp8, "half-open probe serves");
    assert_eq!(resp.degraded, None);
    assert_eq!(
        plane.breaker_state(KernelKind::LowRankFp8),
        BreakerState::Closed
    );
    assert_eq!(counter(&svc, "fault.breaker.recover"), 1);

    // Request 5: business as usual on the recovered kernel.
    let resp = run(5);
    assert_eq!(resp.kernel, KernelKind::LowRankFp8);
    assert_eq!(resp.degraded, None);

    assert_eq!(counter(&svc, "fault.degraded"), 3, "requests 1, 2 and 3");
    assert_eq!(counter(&svc, "fault.injected"), 2, "requests 1 and 2");
}

#[test]
fn corrupt_table_quarantines_at_boot_unless_strict() {
    let dir = std::env::temp_dir().join(format!("lrg_fault_boot_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("autotune.json").to_str().unwrap().to_string();

    let cfg_with = |fault: FaultSettings| ServiceConfig {
        workers: 1,
        autotune: AutotuneSettings {
            enabled: true,
            table_path: Some(path.clone()),
            ..Default::default()
        },
        fault,
        ..Default::default()
    };

    // Degraded boot: corrupt bytes quarantine, the service starts empty.
    std::fs::write(&path, b"{ not json").unwrap();
    let svc = GemmService::start(cfg_with(FaultSettings {
        enabled: true,
        ..Default::default()
    }))
    .unwrap();
    assert_eq!(counter(&svc, "fault.quarantined_table"), 1);
    assert_eq!(counter(&svc, "autotune.warm_start_entries"), 0);
    assert!(
        std::path::Path::new(&format!("{path}.corrupt-1")).exists(),
        "corrupt bytes stay inspectable"
    );
    assert!(
        !std::path::Path::new(&path).exists(),
        "next boot starts clean"
    );
    // The degraded-boot service still serves.
    svc.gemm_blocking(forced_req(96, 1, KernelKind::DenseF32))
        .unwrap();
    drop(svc);

    // strict_boot keeps the historical fail-start behavior.
    std::fs::write(&path, b"{ not json").unwrap();
    let err = GemmService::start(cfg_with(FaultSettings {
        enabled: true,
        strict_boot: true,
        ..Default::default()
    }));
    assert!(err.is_err(), "strict boot must fail on a corrupt table");

    // So does a disabled fault plane (the seed behavior).
    let err = GemmService::start(cfg_with(FaultSettings::default()));
    assert!(err.is_err(), "disabled plane keeps corrupt tables fatal");

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn disabled_plane_is_bitwise_identical_and_interns_no_fault_metrics() {
    // Identity: an enabled-but-inert plane (no injection, healthy
    // breakers) must not perturb result bits relative to the default
    // service — containment wrappers observe jobs, never their math.
    let reqs = || {
        vec![
            forced_req(768, 21, KernelKind::DenseF32),
            forced_req(96, 22, KernelKind::DenseF32),
            forced_req(128, 23, KernelKind::LowRankFp8),
        ]
    };
    let base = GemmService::start(ServiceConfig {
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    let base_out: Vec<Matrix> = reqs()
        .into_iter()
        .map(|r| base.gemm_blocking(r).unwrap().c)
        .collect();

    let armed = GemmService::start(fault_cfg(FaultSettings {
        enabled: true,
        ..Default::default()
    }))
    .unwrap();
    for (i, r) in reqs().into_iter().enumerate() {
        let resp = armed.gemm_blocking(r).unwrap();
        assert_eq!(resp.degraded, None, "healthy plane never degrades");
        assert_bitwise_eq(&resp.c, &base_out[i], &format!("request {i}"));
    }

    // Namespace: the disabled plane interns nothing — the metric names
    // the seed exposes are exactly the names this build exposes.
    for name in base.metrics().counters().keys() {
        assert!(
            !name.starts_with("fault."),
            "disabled plane leaked metric {name}"
        );
        assert_ne!(name.as_str(), "accuracy.probe_shed");
    }
    // And every response from the disabled plane is undegraded by type.
    let resp = base
        .gemm_blocking(forced_req(96, 30, KernelKind::DenseF32))
        .unwrap();
    assert_eq!(resp.degraded, None);
}

#[test]
fn probe_backlog_cap_sheds_instead_of_queueing() {
    let settings = FaultSettings {
        enabled: true,
        ..Default::default()
    };
    let plane = FaultPlane::new(&settings, &MetricsRegistry::new());
    let ex = ShardExecutor::with_metrics(
        ShardPlan::from(&lowrank_gemm::config::schema::ShardSettings::default()),
        Arc::new(MetricsRegistry::new()),
    )
    .with_fault(plane.clone());

    // Occupy the single slot with a job that blocks until released.
    let (started_tx, started_rx) = mpsc::channel();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    assert!(ex.try_execute_background(1, move || {
        started_tx.send(()).unwrap();
        release_rx.recv().ok();
    }));
    started_rx.recv().unwrap();
    assert!(
        !ex.try_execute_background(1, || {}),
        "cap 1 reached: the probe must shed, not queue"
    );

    // Releasing the slot re-admits probes (the Drop guard runs when the
    // job finishes, so poll briefly).
    release_tx.send(()).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if ex.try_execute_background(1, || {}) {
            break;
        }
        assert!(Instant::now() < deadline, "slot never released");
        std::thread::sleep(Duration::from_millis(2));
    }
}
