//! End-to-end tests for the factor-cache plane: a repeated-operand
//! serving workload decomposes each distinct matrix exactly once, hits
//! replay the cold path bit-for-bit, the LRU respects its byte budget
//! strictly, fingerprints cannot collide across same-shape different
//! content, and the default-off config leaves routing bit-identical to
//! the id-only world.

use std::sync::Arc;

use lowrank_gemm::cache::{ContentCache, Fingerprint};
use lowrank_gemm::config::CacheSettings;
use lowrank_gemm::coordinator::{GemmRequest, GemmService, Router, ServiceConfig};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::lowrank::{factorize, FactorCache, LowRankConfig, RankStrategy};

fn cached_service() -> GemmService {
    let cfg = ServiceConfig {
        cache: CacheSettings {
            enabled: true,
            min_dim: 32,
            ..Default::default()
        },
        ..Default::default()
    };
    GemmService::start(cfg).unwrap()
}

fn weight(n: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::seeded(seed);
    Matrix::low_rank_noisy(n, n, (n / 16).max(2), 1e-5, &mut rng)
}

/// The acceptance workload: anonymous repeated operands (the serving
/// pattern the id cache cannot see) decompose exactly once per distinct
/// matrix, asserted through the `cache.hit` / `cache.miss` metrics.
#[test]
fn repeated_workload_decomposes_each_distinct_matrix_once() {
    let svc = cached_service();
    let weights: Vec<Matrix> = (0..3).map(|i| weight(64, 40 + i)).collect();
    let x = weight(64, 50);

    let rounds = 4;
    for round in 0..rounds {
        for w in &weights {
            let req = GemmRequest::new(w.clone(), x.clone())
                .with_kernel(KernelKind::LowRankFp8);
            let resp = svc.gemm_blocking(req).unwrap();
            assert!(resp.rank >= 1, "round {round} must run the factor chain");
        }
    }

    // 4 distinct matrices (3 weights + 1 activation), 2 lookups per
    // request, 12 requests: 4 misses (one cold decomposition each), the
    // remaining 20 lookups are hits.
    let counters = svc.metrics().counters();
    assert_eq!(counters["cache.miss"], 4, "one decomposition per matrix");
    assert_eq!(counters["cache.hit"], 20);
    assert_eq!(counters["cache.insert"], 4);
    let cs = svc.stats().content_cache;
    assert_eq!(cs.entries, 4);
    assert_eq!(cs.misses, 4);
    assert_eq!(cs.hits, 20);
}

/// A cache hit must be indistinguishable from a cold decomposition at
/// the bit level: same factors, same chain, same product bits — both
/// within one service and against a fresh (all-cold) instance.
#[test]
fn hit_is_bitwise_identical_to_cold() {
    let a = weight(96, 60);
    let b = weight(96, 61);
    let req = || GemmRequest::new(a.clone(), b.clone()).with_kernel(KernelKind::LowRankFp8);

    let svc = cached_service();
    let cold = svc.gemm_blocking(req()).unwrap();
    let hit = svc.gemm_blocking(req()).unwrap();
    assert_eq!(
        cold.c.data(),
        hit.c.data(),
        "hit must replay the cold bits exactly"
    );
    assert!(svc.stats().content_cache.hits >= 2);

    // A fresh service's cold path lands on the same bits, so cache state
    // can never be observed through results.
    let fresh = cached_service();
    let fresh_cold = fresh.gemm_blocking(req()).unwrap();
    assert_eq!(cold.c.data(), fresh_cold.c.data());
}

/// LRU eviction is strictly byte-budget-driven: inserts evict least-
/// recently-used entries until the new factor fits, and resident bytes
/// never exceed the budget.
#[test]
fn lru_evicts_strictly_by_byte_budget() {
    let lr_cfg = LowRankConfig {
        rank: RankStrategy::Fixed(4),
        ..Default::default()
    };
    let mut rng = Pcg64::seeded(70);
    let mats: Vec<Matrix> = (0..4).map(|_| Matrix::low_rank(48, 48, 4, &mut rng)).collect();
    let factors: Vec<_> = mats.iter().map(|m| factorize(m, &lr_cfg).unwrap()).collect();
    let fps: Vec<_> = mats.iter().map(Fingerprint::of).collect();
    let bytes = factors[0].storage_bytes();
    assert!(factors.iter().all(|f| f.storage_bytes() == bytes));

    // Budget for exactly three entries.
    let budget = 3 * bytes + bytes / 2;
    let cc = ContentCache::new(budget, 1);
    for (fp, f) in fps.iter().zip(&factors).take(3) {
        assert!(cc.put(*fp, f.clone()));
        assert!(cc.stats().resident_bytes as usize <= budget);
    }
    // Touch 0 and 2; 1 becomes the LRU and must be the one evicted.
    cc.get(fps[0]);
    cc.get(fps[2]);
    assert!(cc.put(fps[3], factors[3].clone()));
    assert!(cc.stats().resident_bytes as usize <= budget, "budget is a hard cap");
    assert!(cc.contains(fps[0]));
    assert!(!cc.contains(fps[1]), "strict LRU victim");
    assert!(cc.contains(fps[2]));
    assert!(cc.contains(fps[3]));
    assert_eq!(cc.stats().evictions, 1);
}

/// Same-shape, different-content matrices get distinct cache entries:
/// the fingerprint digests every element's exact bit pattern, so aliasing
/// would need a 128-bit hash collision (see `cache::fingerprint` docs for
/// the non-adversarial assumption).
#[test]
fn same_shape_different_content_gets_distinct_fingerprints() {
    let mut rng = Pcg64::seeded(80);
    let mut seen = std::collections::HashSet::new();
    for _ in 0..128 {
        let m = Matrix::gaussian(24, 24, &mut rng);
        assert!(
            seen.insert(Fingerprint::of(&m)),
            "two same-shape matrices produced one fingerprint"
        );
    }
    // Structured near-misses: equal except one element, one ulp apart.
    let a = Matrix::gaussian(24, 24, &mut rng);
    let mut b = a.clone();
    let nudged = f32::from_bits(b.data()[0].to_bits() ^ 1);
    b.data_mut()[0] = nudged;
    assert_ne!(Fingerprint::of(&a), Fingerprint::of(&b));
}

/// Acceptance gate: with `[cache]` disabled (the default), every routing
/// decision is bit-identical to a plain id-only router, plans carry no
/// fingerprints, and no content-cache state exists to consult.
#[test]
fn default_off_routing_is_bit_identical() {
    let svc = GemmService::start(ServiceConfig::default()).unwrap();
    assert!(svc.content_cache().is_none());

    let plain = Router::new(
        ServiceConfig::default().router,
        Arc::new(FactorCache::new(ServiceConfig::default().factor_cache_bytes)),
    );
    for (i, n) in [48usize, 96, 256, 512].into_iter().enumerate() {
        let mut rng = Pcg64::seeded(900 + i as u64);
        let req = GemmRequest::new(
            Matrix::gaussian(n, n, &mut rng),
            Matrix::gaussian(n, n, &mut rng),
        );
        let a = svc.plan(&req);
        let b = plain.route(&req);
        assert_eq!(a.choice.kind, b.choice.kind, "n={n}");
        assert_eq!(
            a.choice.cost.time_s.to_bits(),
            b.choice.cost.time_s.to_bits(),
            "n={n}: disabled cache must not perturb a single cost bit"
        );
        assert_eq!(a.factors_cached, b.factors_cached);
        assert_eq!(a.hints, lowrank_gemm::cache::FactorHints::default());
    }
    assert_eq!(svc.stats().content_cache.entries, 0);
}

/// `[cache].fp8` stores factors through the FP8 codecs: resident memory
/// shrinks ~4x vs f32 factors while hits still replay the (FP8) cold
/// path bit-for-bit.
#[test]
fn fp8_storage_shrinks_resident_bytes_and_stays_bit_stable() {
    let mut cfg = ServiceConfig {
        cache: CacheSettings {
            enabled: true,
            min_dim: 32,
            fp8: true,
            ..Default::default()
        },
        ..Default::default()
    };
    cfg.router.storage = lowrank_gemm::fp8::StorageFormat::F32;
    let svc = GemmService::start(cfg).unwrap();

    let a = weight(64, 62);
    let b = weight(64, 63);
    let req = || GemmRequest::new(a.clone(), b.clone()).with_kernel(KernelKind::LowRankFp8);
    let cold = svc.gemm_blocking(req()).unwrap();
    let hit = svc.gemm_blocking(req()).unwrap();
    assert_eq!(cold.c.data(), hit.c.data());

    let cc = svc.content_cache().unwrap();
    let cached = cc.get(Fingerprint::of(&a)).expect("factor resident");
    assert_eq!(
        cached.u.format.bytes_per_element(),
        1,
        "factors must be stored FP8-encoded"
    );
    assert!(cached.memory_saving() > 0.5);
}
