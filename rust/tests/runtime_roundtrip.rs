//! Integration: AOT artifacts through the PJRT runtime vs the CPU substrate.
//!
//! This is the cross-layer correctness bar: the Pallas-lowered HLO
//! (L1+L2) must agree with the native Rust implementations (L3 substrate)
//! on every op kind the manifest serves. Requires `make artifacts`.

use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::lowrank::{factorize, lowrank_matmul, LowRankConfig, RankStrategy};
use lowrank_gemm::runtime::{Manifest, XlaExecutor, XlaRuntime};

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping runtime integration test: run `make artifacts` first");
        None
    }
}

fn f32_cfg(rank: usize) -> LowRankConfig {
    LowRankConfig {
        rank: RankStrategy::Fixed(rank),
        storage: lowrank_gemm::fp8::StorageFormat::F32,
        ..Default::default()
    }
}

#[test]
fn manifest_loads_and_indexes() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(dir).unwrap();
    assert!(m.entries().len() >= 30, "expected full lattice, got {}", m.entries().len());
    for op in ["dense_f32", "dense_f16", "dense_fp8"] {
        for n in [64, 128, 256] {
            assert!(m.lookup(op, n, 0).is_some(), "{op} n={n} missing");
        }
    }
    assert!(m.lookup("rsvd", 128, 16).is_some());
    assert!(m.lookup("lowrank_apply", 256, 32).is_some());
}

#[test]
fn dense_f32_artifact_matches_cpu_gemm() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(dir).unwrap();
    let mut rng = Pcg64::seeded(11);
    for n in [64usize, 128] {
        let a = Matrix::gaussian(n, n, &mut rng);
        let b = Matrix::gaussian(n, n, &mut rng);
        let c = rt.dense_gemm("dense_f32", &a, &b).unwrap();
        let exact = a.matmul(&b);
        let err = c.rel_frobenius_distance(&exact);
        assert!(err < 1e-5, "n={n}: err {err}");
    }
}

#[test]
fn dense_fp8_artifact_error_band() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(dir).unwrap();
    let mut rng = Pcg64::seeded(12);
    let n = 64;
    let a = Matrix::gaussian(n, n, &mut rng);
    let b = Matrix::gaussian(n, n, &mut rng);
    let c = rt.dense_gemm("dense_fp8", &a, &b).unwrap();
    let exact = a.matmul(&b);
    let err = c.rel_frobenius_distance(&exact);
    // Same §5.4 band the CPU fp8 substrate lands in.
    assert!(err > 1e-4 && err < 0.15, "err {err}");
}

#[test]
fn dense_f16_artifact_between_f32_and_fp8() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(dir).unwrap();
    let mut rng = Pcg64::seeded(13);
    let n = 64;
    let a = Matrix::gaussian(n, n, &mut rng);
    let b = Matrix::gaussian(n, n, &mut rng);
    let exact = a.matmul(&b);
    let e16 = rt.dense_gemm("dense_f16", &a, &b).unwrap().rel_frobenius_distance(&exact);
    let e8 = rt.dense_gemm("dense_fp8", &a, &b).unwrap().rel_frobenius_distance(&exact);
    assert!(e16 < e8, "f16 {e16} should beat fp8 {e8}");
    assert!(e16 > 1e-7 && e16 < 5e-3, "f16 err {e16}");
}

#[test]
fn lowrank_apply_artifact_matches_cpu_chain() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(dir).unwrap();
    let mut rng = Pcg64::seeded(14);
    let (n, r) = (128usize, 16usize);
    let a = Matrix::low_rank_noisy(n, n, r / 2, 1e-5, &mut rng);
    let b = Matrix::low_rank_noisy(n, n, r / 2, 1e-5, &mut rng);
    let fa = factorize(&a, &f32_cfg(r)).unwrap();
    let fb = factorize(&b, &f32_cfg(r)).unwrap();

    // CPU chain.
    let cpu = lowrank_matmul(&fa, &fb);

    // Artifact chain: U_A, core, V_Bᵀ.
    let core = fa.core_with(&fb).unwrap();
    let out = rt
        .run(
            &format!("lowrank_apply_n{n}_r{r}"),
            &[&fa.u_dense(), &core, &fb.vt_dense()],
        )
        .unwrap()
        .remove(0);
    let err = out.rel_frobenius_distance(&cpu);
    assert!(err < 1e-4, "xla vs cpu chain err {err}");

    // And both approximate the dense product.
    let exact = a.matmul(&b);
    assert!(out.rel_frobenius_distance(&exact) < 0.02);
}

#[test]
fn rsvd_artifact_reconstructs_low_rank_input() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(dir).unwrap();
    let mut rng = Pcg64::seeded(15);
    let (n, r) = (128usize, 16usize);
    let l = r + rt.manifest().oversample;
    let a = Matrix::low_rank(n, n, r / 2, &mut rng);
    let omega = Matrix::gaussian(n, l, &mut rng);

    let outs = rt.run(&format!("rsvd_n{n}_r{r}"), &[&a, &omega]).unwrap();
    let (u, s, vt) = (&outs[0], &outs[1], &outs[2]);
    assert_eq!(u.shape(), (n, r));
    assert_eq!(s.shape(), (1, r));
    assert_eq!(vt.shape(), (r, n));

    // Reconstruct U diag(s) Vᵀ and compare.
    let mut us = u.clone();
    us.scale_cols_in_place(s.data());
    let rec = us.matmul(vt);
    let err = rec.rel_frobenius_distance(&a);
    assert!(err < 1e-3, "rsvd artifact reconstruction err {err}");

    // Singular values descend.
    for w in s.data().windows(2) {
        assert!(w[0] >= w[1] - 1e-5);
    }
}

#[test]
fn e2e_artifact_runs_cold_path() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(dir).unwrap();
    let mut rng = Pcg64::seeded(16);
    let (n, r) = (128usize, 16usize);
    let l = r + rt.manifest().oversample;
    let a = Matrix::low_rank(n, n, r / 2, &mut rng);
    let b = Matrix::low_rank(n, n, r / 2, &mut rng);
    let oa = Matrix::gaussian(n, l, &mut rng);
    let ob = Matrix::gaussian(n, l, &mut rng);

    let c = rt
        .run("lowrank_e2e_n128_r16", &[&a, &b, &oa, &ob])
        .unwrap()
        .remove(0);
    let exact = a.matmul(&b);
    let err = c.rel_frobenius_distance(&exact);
    assert!(err < 1e-3, "e2e err {err}");
}

#[test]
fn executable_cache_compiles_once() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(dir).unwrap();
    let mut rng = Pcg64::seeded(17);
    let a = Matrix::gaussian(64, 64, &mut rng);
    let b = Matrix::gaussian(64, 64, &mut rng);
    assert_eq!(rt.compiles(), 0);
    rt.dense_gemm("dense_f32", &a, &b).unwrap();
    assert_eq!(rt.compiles(), 1);
    rt.dense_gemm("dense_f32", &a, &b).unwrap();
    assert_eq!(rt.compiles(), 1, "second call must hit the cache");
}

#[test]
fn run_validates_shapes_and_names() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(dir).unwrap();
    let a = Matrix::zeros(64, 64);
    // Unknown artifact.
    assert!(rt.run("nonexistent_op", &[&a]).is_err());
    // Wrong arity.
    assert!(rt.run("dense_f32_n64", &[&a]).is_err());
    // Wrong element count.
    let bad = Matrix::zeros(32, 32);
    assert!(rt.run("dense_f32_n64", &[&a, &bad]).is_err());
}

#[test]
fn executor_thread_serves_concurrent_callers() {
    let Some(dir) = artifacts_dir() else { return };
    let ex = XlaExecutor::start(dir).unwrap();
    let mut rng = Pcg64::seeded(18);
    let a = Matrix::gaussian(64, 64, &mut rng);
    let b = Matrix::gaussian(64, 64, &mut rng);
    let exact = a.matmul(&b);

    let mut joins = Vec::new();
    for _ in 0..4 {
        let h = ex.handle();
        let (a, b, exact) = (a.clone(), b.clone(), exact.clone());
        joins.push(std::thread::spawn(move || {
            let c = h.run("dense_f32_n64", vec![a, b]).unwrap().remove(0);
            assert!(c.rel_frobenius_distance(&exact) < 1e-5);
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    // All four callers shared one compilation.
    assert_eq!(ex.compile_count().unwrap(), 1);
}
