//! Telemetry overhead — the observability-plane instrument.
//!
//! Two questions, answered with numbers:
//!
//! 1. What does one metric update cost? Compares the pre-rewrite design
//!    (a mutex-guarded name→value map, re-locked and re-hashed on every
//!    update) against the string API (read-lock + hash at steady state)
//!    and pre-registered interned handles (plain atomics) — both
//!    single-threaded and under 4-way contention, where the mutex
//!    registry serializes and the striped handles don't.
//! 2. What does tracing cost a request? End-to-end `gemm_blocking`
//!    latency with `[trace]` off (the default) vs on.
//!
//! Every measurement prints one JSON record
//! (`{"bench":"telemetry_overhead","case":…}`) for CI's bench-smoke
//! artifact collection, same shape as `hotpath_micro`.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use lowrank_gemm::bench_harness::{bench, config_from_env, Measurement, Table};
use lowrank_gemm::config::TraceSettings;
use lowrank_gemm::coordinator::{GemmRequest, GemmService, ServiceConfig};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::metrics::{Histogram, MetricsRegistry};

fn json_row(case: &str, n: usize, m: &Measurement) {
    println!(
        "{{\"bench\":\"telemetry_overhead\",\"case\":\"{case}\",\"n\":{n},\
         \"mean_s\":{:.6e},\"min_s\":{:.6e},\"max_s\":{:.6e},\"stddev_s\":{:.6e},\
         \"iters\":{}}}",
        m.mean_s, m.min_s, m.max_s, m.stddev_s, m.iters
    );
}

/// The pre-rewrite metrics design, reconstructed inline for comparison:
/// every update takes one global mutex and hashes the metric name.
#[derive(Default)]
struct LegacyRegistry {
    counters: Mutex<BTreeMap<String, u64>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

impl LegacyRegistry {
    fn count(&self, name: &str, v: u64) {
        let mut g = self.counters.lock().unwrap();
        match g.get_mut(name) {
            Some(c) => *c += v,
            None => {
                g.insert(name.to_string(), v);
            }
        }
    }

    fn observe(&self, name: &str, v: f64) {
        let mut g = self.histograms.lock().unwrap();
        match g.get_mut(name) {
            Some(h) => h.record(v),
            None => {
                let mut h = Histogram::new();
                h.record(v);
                g.insert(name.to_string(), h);
            }
        }
    }
}

const OPS: usize = 10_000;

fn metrics_hot_path() {
    let cfg = config_from_env();
    let mut table = Table::new(
        "Metric update cost [ns/op, count+observe pair]",
        &["variant", "1 thread", "4 threads"],
    );

    // One "op" is a counter bump plus a histogram sample — the shape of
    // every instrumented site on the serving path.
    let legacy = Arc::new(LegacyRegistry::default());
    legacy.count("bench.ops", 0);
    legacy.observe("bench.lat_us", 1.0);
    let registry = Arc::new(MetricsRegistry::new());
    let counter = registry.counter("bench.ops");
    let hist = registry.histogram("bench.lat_us");
    registry.count("bench.ops", 0);
    registry.observe("bench.lat_us", 1.0);

    let contended = |op: Arc<dyn Fn() + Send + Sync>| {
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let op = op.clone();
                std::thread::spawn(move || {
                    for _ in 0..OPS {
                        op();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    };

    let mut results: Vec<(&str, Measurement, Measurement)> = Vec::new();
    {
        let l = legacy.clone();
        let serial = bench(&cfg, || {
            for i in 0..OPS {
                l.count("bench.ops", 1);
                l.observe("bench.lat_us", i as f64 + 1.0);
            }
        });
        let l = legacy.clone();
        let par = bench(&cfg, || {
            let l = l.clone();
            contended(Arc::new(move || {
                l.count("bench.ops", 1);
                l.observe("bench.lat_us", 1.5);
            }));
        });
        results.push(("legacy_mutex", serial, par));
    }
    {
        let r = registry.clone();
        let serial = bench(&cfg, || {
            for i in 0..OPS {
                r.count("bench.ops", 1);
                r.observe("bench.lat_us", i as f64 + 1.0);
            }
        });
        let r = registry.clone();
        let par = bench(&cfg, || {
            let r = r.clone();
            contended(Arc::new(move || {
                r.count("bench.ops", 1);
                r.observe("bench.lat_us", 1.5);
            }));
        });
        results.push(("string_api", serial, par));
    }
    {
        let (c, h) = (counter.clone(), hist.clone());
        let serial = bench(&cfg, || {
            for i in 0..OPS {
                c.inc();
                h.observe(i as f64 + 1.0);
            }
        });
        let (c, h) = (counter.clone(), hist.clone());
        let par = bench(&cfg, || {
            let (c, h) = (c.clone(), h.clone());
            contended(Arc::new(move || {
                c.inc();
                h.observe(1.5);
            }));
        });
        results.push(("interned_handles", serial, par));
    }

    for (name, serial, par) in &results {
        table.row(&[
            name.to_string(),
            format!("{:8.1}", serial.mean_s / OPS as f64 * 1e9),
            format!("{:8.1}", par.mean_s / (4 * OPS) as f64 * 1e9),
        ]);
        json_row(&format!("metrics_{name}_1t"), OPS, serial);
        json_row(&format!("metrics_{name}_4t"), 4 * OPS, par);
    }
    table.print();
    println!();
}

fn traced_request_latency() {
    let cfg = config_from_env();
    let n = 256;
    let mut rng = Pcg64::seeded(71);
    let a = Matrix::gaussian(n, n, &mut rng);
    let b = Matrix::gaussian(n, n, &mut rng);

    let run = |enabled: bool| {
        let svc = GemmService::start(ServiceConfig {
            trace: TraceSettings {
                enabled,
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        bench(&cfg, || {
            svc.gemm_blocking(
                GemmRequest::new(a.clone(), b.clone()).with_kernel(KernelKind::DenseF32),
            )
            .unwrap();
        })
    };
    let off = run(false);
    let on = run(true);

    let mut table = Table::new(
        "Request latency, tracing off vs on [us]",
        &["N", "untraced", "traced", "overhead"],
    );
    table.row(&[
        n.to_string(),
        format!("{:8.1}", off.mean_s * 1e6),
        format!("{:8.1}", on.mean_s * 1e6),
        format!("{:+6.2}%", (on.mean_s / off.mean_s - 1.0) * 100.0),
    ]);
    table.print();
    println!();
    json_row("request_untraced", n, &off);
    json_row("request_traced", n, &on);
}

fn main() {
    metrics_hot_path();
    traced_request_latency();
}
