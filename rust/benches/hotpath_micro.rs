//! Hot-path microbenchmarks — the §Perf instrument.
//!
//! Each block measures one layer-3 hot path in isolation so the
//! optimization loop (EXPERIMENTS.md §Perf) can attribute wins/regressions:
//! GEMM kernels, factor chain, codecs, cache, router, batcher, service.
//!
//! Besides the human-readable tables, every measurement also prints one
//! JSON record (`{"bench":"hotpath_micro","case":…,"n":…,"mean_s":…}`)
//! so CI's bench-smoke job can collect `BENCH_*.json` artifacts and
//! downstream tooling can diff runs.

use lowrank_gemm::bench_harness::{bench, config_from_env, Measurement, Table};
use lowrank_gemm::coordinator::{Batcher, BucketKey, GemmRequest, GemmService, Router, RouterConfig, ServiceConfig};
use lowrank_gemm::fp8::{dequantize, quantize, quantized_matmul, quantized_matmul_fused, StorageFormat};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::{
    gemm_blocked, gemm_blocked_unpacked, gemm_flops, gemm_naive, Matrix, Pcg64,
};
use lowrank_gemm::lowrank::{factorize, lowrank_matmul, FactorCache, LowRankConfig, RankStrategy};
use lowrank_gemm::metrics::MetricsRegistry;
use lowrank_gemm::shard::{ShardExecutor, ShardPlan};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn json_row(case: &str, n: usize, m: &Measurement) {
    println!(
        "{{\"bench\":\"hotpath_micro\",\"case\":\"{case}\",\"n\":{n},\
         \"mean_s\":{:.6e},\"min_s\":{:.6e},\"max_s\":{:.6e},\"stddev_s\":{:.6e},\
         \"iters\":{}}}",
        m.mean_s, m.min_s, m.max_s, m.stddev_s, m.iters
    );
}

fn gemm_kernels() {
    let cfg = config_from_env();
    let mut rng = Pcg64::seeded(31);
    let mut table = Table::new(
        "GEMM kernels [GFLOPS]",
        &["N", "naive", "blocked", "blocked/naive"],
    );
    for n in [64usize, 128, 256, 512] {
        let a = Matrix::gaussian(n, n, &mut rng);
        let b = Matrix::gaussian(n, n, &mut rng);
        let flops = gemm_flops(n, n, n);
        let mn = bench(&cfg, || {
            gemm_naive(&a, &b).unwrap();
        });
        let mb = bench(&cfg, || {
            gemm_blocked(&a, &b).unwrap();
        });
        table.row(&[
            n.to_string(),
            format!("{:7.2}", mn.throughput(flops) / 1e9),
            format!("{:7.2}", mb.throughput(flops) / 1e9),
            format!("{:5.2}x", mn.mean_s / mb.mean_s),
        ]);
        json_row("gemm_naive", n, &mn);
        json_row("gemm_blocked", n, &mb);
    }
    table.print();
    println!();
}

fn packed_paths() {
    // Tentpole instrument (PR 5): packed vs unpacked dense kernels, and
    // fused decode-into-pack vs decode-then-pack on the FP8 path. The
    // pairs are bitwise-identical — only the memory traffic differs.
    let cfg = config_from_env();
    let mut rng = Pcg64::seeded(36);
    let mut table = Table::new(
        "Packed-operand hot path [GFLOPS]",
        &["N", "unpacked", "packed", "fp8 unfused", "fp8 fused"],
    );
    let fmt = StorageFormat::Fp8(lowrank_gemm::fp8::Fp8Format::E4M3);
    for n in [256usize, 512] {
        let a = Matrix::gaussian(n, n, &mut rng);
        let b = Matrix::gaussian(n, n, &mut rng);
        let flops = gemm_flops(n, n, n);
        let mu = bench(&cfg, || {
            gemm_blocked_unpacked(&a, &b).unwrap();
        });
        let mp = bench(&cfg, || {
            gemm_blocked(&a, &b).unwrap();
        });
        let mqu = bench(&cfg, || {
            quantized_matmul(&a, &b, fmt);
        });
        let mqf = bench(&cfg, || {
            quantized_matmul_fused(&a, &b, fmt);
        });
        table.row(&[
            n.to_string(),
            format!("{:7.2}", mu.throughput(flops) / 1e9),
            format!("{:7.2}", mp.throughput(flops) / 1e9),
            format!("{:7.2}", mqu.throughput(flops) / 1e9),
            format!("{:7.2}", mqf.throughput(flops) / 1e9),
        ]);
        json_row("gemm_blocked_unpacked", n, &mu);
        json_row("gemm_blocked_packed", n, &mp);
        json_row("fp8_decode_then_pack", n, &mqu);
        json_row("fp8_fused_decode_pack", n, &mqf);
    }
    table.print();

    // Pack-once/reuse-many on the shard plane: one multi-tile run, then
    // report how many per-tile re-packs the shared panels saved. CI's
    // bench-smoke job fails when this ever reads zero.
    let n = 768;
    let a = Matrix::gaussian(n, n, &mut rng);
    let b = Matrix::gaussian(n, n, &mut rng);
    let metrics = Arc::new(MetricsRegistry::new());
    let ex = ShardExecutor::with_metrics(ShardPlan::default(), metrics.clone());
    ex.gemm(&a, &b).unwrap();
    let counters = metrics.counters();
    let reuse = counters.get("pack.reuse").copied().unwrap_or(0);
    let panels = counters.get("pack.panels").copied().unwrap_or(0);
    println!("shard pack reuse @N={n}: {panels} panels packed, {reuse} re-packs saved");
    println!(
        "{{\"bench\":\"hotpath_micro\",\"case\":\"pack_reuse_events\",\"n\":{n},\
         \"mean_s\":0.0,\"min_s\":0.0,\"max_s\":0.0,\"stddev_s\":0.0,\
         \"iters\":1,\"reuse\":{reuse},\"panels\":{panels}}}"
    );
    println!();
}

fn factor_chain() {
    let cfg = config_from_env();
    let mut rng = Pcg64::seeded(32);
    let mut table = Table::new(
        "Warm factor-chain [ms] (r = N/16) vs dense",
        &["N", "chain", "dense", "speedup"],
    );
    for n in [128usize, 256, 512, 768] {
        let r = n / 16;
        let a = Matrix::low_rank_noisy(n, n, r, 1e-4, &mut rng);
        let b = Matrix::low_rank_noisy(n, n, r, 1e-4, &mut rng);
        let lr_cfg = LowRankConfig {
            rank: RankStrategy::Fixed(r),
            ..Default::default()
        };
        let fa = factorize(&a, &lr_cfg).unwrap();
        let fb = factorize(&b, &lr_cfg).unwrap();
        let mc = bench(&cfg, || {
            lowrank_matmul(&fa, &fb);
        });
        let md = bench(&cfg, || {
            gemm_blocked(&a, &b).unwrap();
        });
        table.row(&[
            n.to_string(),
            format!("{:8.2}", mc.mean_s * 1e3),
            format!("{:8.2}", md.mean_s * 1e3),
            format!("{:5.2}x", md.mean_s / mc.mean_s),
        ]);
        json_row("factor_chain_warm", n, &mc);
        json_row("factor_chain_dense_baseline", n, &md);
    }
    table.print();
    println!();
}

fn codecs() {
    let cfg = config_from_env();
    let mut rng = Pcg64::seeded(33);
    let n = 512;
    let a = Matrix::gaussian(n, n, &mut rng);
    let mut table = Table::new(
        "Quantize + dequantize round-trip [M elems/s] (512x512)",
        &["format", "quantize", "dequantize"],
    );
    for fmt in [
        StorageFormat::F16,
        StorageFormat::Bf16,
        StorageFormat::Fp8(lowrank_gemm::fp8::Fp8Format::E4M3),
        StorageFormat::Fp8(lowrank_gemm::fp8::Fp8Format::E5M2),
    ] {
        let q = quantize(&a, fmt);
        let mq = bench(&cfg, || {
            quantize(&a, fmt);
        });
        let md = bench(&cfg, || {
            dequantize(&q);
        });
        let elems = (n * n) as f64;
        table.row(&[
            fmt.name().to_string(),
            format!("{:8.1}", mq.throughput(elems) / 1e6),
            format!("{:8.1}", md.throughput(elems) / 1e6),
        ]);
        json_row(&format!("quantize_{}", fmt.name()), n, &mq);
        json_row(&format!("dequantize_{}", fmt.name()), n, &md);
    }
    table.print();
    println!();
}

fn cache_and_router() {
    let cfg = config_from_env();
    let mut rng = Pcg64::seeded(34);
    let cache = Arc::new(FactorCache::new(256 << 20));
    let lr_cfg = LowRankConfig {
        rank: RankStrategy::Fixed(8),
        ..Default::default()
    };
    for i in 0..32u64 {
        let m = Matrix::low_rank(96, 96, 8, &mut rng);
        cache.put(i, factorize(&m, &lr_cfg).unwrap());
    }
    let mhit = bench(&cfg, || {
        for i in 0..32u64 {
            std::hint::black_box(cache.get(i));
        }
    });
    println!(
        "factor cache: {:.2} M gets/s (hit, incl. clone)",
        32.0 / mhit.mean_s / 1e6
    );
    json_row("factor_cache_get", 96, &mhit);

    let router = Router::new(RouterConfig::default(), cache.clone());
    let a = Matrix::zeros(1024, 1024);
    let b = Matrix::zeros(1024, 1024);
    let req = GemmRequest::new(a, b);
    let mr = bench(&cfg, || {
        for _ in 0..100 {
            std::hint::black_box(router.route(&req));
        }
    });
    println!("router: {:.2} M route()/s", 100.0 / mr.mean_s / 1e6);
    json_row("router_route", 1024, &mr);

    let mut batcher: Batcher<u32> = Batcher::new(8, Duration::from_micros(100));
    let key = BucketKey::of(KernelKind::DenseF32, 256, 256, 256);
    let mb = bench(&cfg, || {
        let now = Instant::now();
        for i in 0..1000 {
            std::hint::black_box(batcher.push(key, i, now));
        }
        batcher.flush_all();
    });
    println!("batcher: {:.2} M push()/s\n", 1000.0 / mb.mean_s / 1e6);
    json_row("batcher_push", 256, &mb);
}

fn service_request_path() {
    let cfg = config_from_env();
    let svc_cfg = ServiceConfig {
        workers: 2,
        ..Default::default()
    };
    let svc = GemmService::start(svc_cfg).unwrap();
    let mut rng = Pcg64::seeded(35);
    let n = 96;
    let a = Matrix::gaussian(n, n, &mut rng);
    let b = Matrix::gaussian(n, n, &mut rng);

    // Throughput under async pipelining (16 in flight).
    let m = bench(&cfg, || {
        let rxs: Vec<_> = (0..16)
            .map(|_| svc.submit(GemmRequest::new(a.clone(), b.clone())).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
    });
    println!(
        "service @N={n}: {:.0} req/s pipelined (batching on), queue+exec p50 via metrics:",
        16.0 / m.mean_s
    );
    json_row("service_pipelined_16", n, &m);
    for (name, s) in svc.metrics().histogram_summaries() {
        println!("  {name}: p50 {:.0} p99 {:.0} (n={})", s.p50, s.p99, s.count);
    }
}

fn main() {
    gemm_kernels();
    packed_paths();
    factor_chain();
    codecs();
    cache_and_router();
    service_request_path();
}
