//! Ablation A2 + error study E1: the four rank-selection strategies
//! (paper §3.2) across spectrum families, and the §5.4.4 ε ≈ √(n/r)
//! error-scaling claim, measured.

use lowrank_gemm::bench_harness::{bench, config_from_env, Table};
use lowrank_gemm::gpu_sim::DeviceProfile;
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::lowrank::{
    eckart_young_rel_error, factorize, predicted_rel_error, LowRankConfig, RankStrategy,
};
use lowrank_gemm::trace::{matrix_with_spectrum, SpectrumKind};

fn strategies() -> Vec<(String, RankStrategy)> {
    vec![
        ("fixed r=32".into(), RankStrategy::Fixed(32)),
        ("fraction 5%".into(), RankStrategy::FixedFraction(0.05)),
        ("energy 99%".into(), RankStrategy::EnergyFraction(0.99)),
        ("error ≤2%".into(), RankStrategy::ErrorBound(0.02)),
        (
            "hw-aware 15%".into(),
            RankStrategy::HardwareAware {
                memory_fraction: 0.15,
                granule: 16,
            },
        ),
    ]
}

fn strategy_table() {
    let cfg = config_from_env();
    let n = 256;
    let mut rng = Pcg64::seeded(5);
    let spectra = [
        SpectrumKind::ExponentialDecay,
        SpectrumKind::PowerLaw,
        SpectrumKind::Flat,
    ];

    for kind in spectra {
        let a = matrix_with_spectrum(n, kind, &mut rng);
        let mut table = Table::new(
            &format!("Rank strategies on {} spectrum (N={n})", kind.name()),
            &["Strategy", "rank", "rel err", "mem saving", "factorize ms"],
        );
        for (name, strat) in strategies() {
            let lr_cfg = LowRankConfig {
                rank: strat,
                ..Default::default()
            };
            let f = factorize(&a, &lr_cfg).unwrap();
            let m = bench(&cfg, || {
                factorize(&a, &lr_cfg).unwrap();
            });
            table.row(&[
                name,
                f.rank().to_string(),
                format!("{:.2e}", f.measured_error(&a)),
                format!("{:5.1}%", 100.0 * f.memory_saving()),
                format!("{:7.2}", m.mean_s * 1e3),
            ]);
        }
        table.print();
        println!();
    }
}

fn energy_adaptivity() {
    // §3.2's core claim: energy-based selection adapts the rank to the
    // spectrum's decay rate.
    let n = 192;
    let mut rng = Pcg64::seeded(6);
    let mut table = Table::new(
        "Energy-99% adaptivity vs spectral decay (N=192)",
        &["decay ρ (σ_j = ρ^j)", "selected rank", "measured err"],
    );
    for rho in [0.5f32, 0.7, 0.85, 0.95, 0.99] {
        let sv: Vec<f32> = (0..n).map(|j| rho.powi(j as i32)).collect();
        let a = Matrix::with_spectrum(n, n, &sv, &mut rng);
        let f = factorize(
            &a,
            &LowRankConfig {
                rank: RankStrategy::EnergyFraction(0.99),
                ..Default::default()
            },
        )
        .unwrap();
        table.row(&[
            format!("{rho:.2}"),
            f.rank().to_string(),
            format!("{:.2e}", f.measured_error(&a)),
        ]);
    }
    table.print();
    println!();
}

fn error_scaling_claim() {
    // §5.4.4: "the relative error scales as ε ≈ √(n/r)". Audit it: for a
    // *flat* (worst-case) spectrum the Eckart-Young error is
    // √(1 - r/n) — bounded by 1 — not √(n/r) (which exceeds 1 for r < n).
    // We print the paper's predictor next to the true optimal error on
    // flat and decaying spectra; EXPERIMENTS.md §E1 discusses the gap.
    let n = 256;
    let mut rng = Pcg64::seeded(7);
    let mut table = Table::new(
        "§5.4.4 audit — paper's ε≈√(n/r) vs measured truncation error (N=256)",
        &["r", "raw √(n/r)", "calibrated c√(n/r)", "EY flat", "measured flat", "EY decay", "measured decay"],
    );
    let flat_sv: Vec<f32> = (0..n).map(|_| 1.0).collect();
    let decay_sv: Vec<f32> = (0..n).map(|j| (0.97f32).powi(j as i32)).collect();
    let a_flat = Matrix::with_spectrum(n, n, &flat_sv, &mut rng);
    let a_decay = Matrix::with_spectrum(n, n, &decay_sv, &mut rng);
    for r in [16usize, 32, 64, 128] {
        let cfgr = LowRankConfig {
            rank: RankStrategy::Fixed(r),
            method: lowrank_gemm::lowrank::DecompMethod::ExactSvd,
            storage: lowrank_gemm::fp8::StorageFormat::F32,
            ..Default::default()
        };
        let mf = factorize(&a_flat, &cfgr).unwrap().measured_error(&a_flat);
        let md = factorize(&a_decay, &cfgr).unwrap().measured_error(&a_decay);
        table.row(&[
            r.to_string(),
            format!("{:.2}", ((n as f32) / (r as f32)).sqrt()),
            format!("{:.4}", predicted_rel_error(n, r)),
            format!("{:.3}", eckart_young_rel_error(&flat_sv, r)),
            format!("{mf:.3}"),
            format!("{:.3}", eckart_young_rel_error(&decay_sv, r)),
            format!("{md:.3}"),
        ]);
    }
    table.print();
    println!("(measured matches Eckart-Young; the paper's √(n/r) is not a valid error model.)\n");
}

fn hardware_aware_scales_with_device() {
    let mut table = Table::new(
        "Hardware-aware rank vs device memory (m=n=8192 route-time estimate)",
        &["device", "selected rank"],
    );
    for d in [
        DeviceProfile::rtx4090(),
        DeviceProfile::h200(),
        DeviceProfile::b200(),
    ] {
        let r = lowrank_gemm::lowrank::select_rank(
            &RankStrategy::HardwareAware {
                memory_fraction: 0.15,
                granule: 64,
            },
            8192,
            8192,
            &[],
            &d,
        );
        table.row(&[d.name.to_string(), r.to_string()]);
    }
    table.print();
}

fn main() {
    strategy_table();
    energy_adaptivity();
    error_scaling_claim();
    hardware_aware_scales_with_device();
}
