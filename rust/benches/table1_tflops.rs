//! Table 1 reproduction: peak TFLOPS per method at N ∈ {1024, 4096, 16384,
//! 20480} on the RTX 4090 roofline model, plus a real-CPU cross-check of
//! the same pipelines at substrate scale.
//!
//! Run: `cargo bench --bench table1_tflops` (LRG_BENCH_QUICK=1 for CI).
//!
//! The simulated block regenerates the paper's table from first
//! principles (bytes, flops, launches — see gpu_sim::roofline); the
//! measured block runs the *actual* kernels on this machine at sizes the
//! 1-core host can complete, proving the same ordering/crossover shape
//! with real numerics. EXPERIMENTS.md §T1 compares both against the paper.

use lowrank_gemm::bench_harness::{bench, config_from_env, Table};
use lowrank_gemm::coordinator::{Backend, GemmRequest, GemmService, ServiceConfig};
use lowrank_gemm::gpu_sim::{DeviceProfile, Roofline};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::{gemm_flops, Matrix, Pcg64};
use lowrank_gemm::lowrank::{FactorCache, LowRankConfig, RankStrategy};
use std::sync::Arc;

/// Paper Table 1, verbatim, for side-by-side printing.
const PAPER: [(&str, [f64; 4]); 5] = [
    ("PyTorch FP32", [38.0, 45.0, 52.0, 49.0]),
    ("TorchCompile FP16", [21.0, 93.0, 135.0, 139.0]),
    ("cuBLAS Optimized FP8", [18.0, 88.0, 132.0, 137.0]),
    ("LowRank FP8", [0.5, 18.0, 172.0, 209.0]),
    ("LowRank Auto", [0.5, 21.0, 278.0, 378.0]),
];

const SIZES: [usize; 4] = [1024, 4096, 16384, 20480];

fn paper_rank(n: usize) -> usize {
    // The paper's operating point: r = 512 at N = 20480 (§5.5), i.e. N/40.
    (n / 40).max(16)
}

fn simulated_table() {
    let rl = Roofline::new(DeviceProfile::rtx4090());
    let mut table = Table::new(
        "Table 1 — peak TFLOPS on RTX 4090 (simulated | paper)",
        &["Method", "N=1024", "N=4096", "N=16384", "N=20480"],
    );
    for (name, paper_row) in PAPER {
        let mut cells = vec![name.to_string()];
        for (i, &n) in SIZES.iter().enumerate() {
            let r = paper_rank(n);
            let sim = match name {
                "PyTorch FP32" => rl.pytorch_f32(n),
                "TorchCompile FP16" => rl.torchcompile_f16(n),
                "cuBLAS Optimized FP8" => rl.cublas_fp8(n),
                "LowRank FP8" => rl.lowrank_fp8(n, r),
                "LowRank Auto" => rl.lowrank_auto(n, r),
                _ => unreachable!(),
            };
            cells.push(format!("{:7.1} | {:6.1}", sim.tflops, paper_row[i]));
        }
        table.row(&cells);
    }
    table.print();

    // The paper's headline ratios, recomputed from the simulated rows.
    let auto = rl.lowrank_auto(20480, paper_rank(20480)).tflops;
    let f32t = rl.pytorch_f32(20480).tflops;
    let fp8t = rl.cublas_fp8(20480).tflops;
    println!(
        "headline: LowRankAuto/PyTorchF32 = {:.1}x (paper 7.7x), /cuBLAS-FP8 = {:.1}x (paper 2.8x)\n",
        auto / f32t,
        auto / fp8t
    );
}

fn measured_table() {
    // Real execution on this host: same five pipelines, substrate scale.
    // Weights are preloaded (offline decomposition) for the warm low-rank
    // rows; LowRank FP8 runs cold to mirror the paper's harness.
    let cfg = config_from_env();
    let sizes = [128usize, 256, 384, 512];
    let mut rng = Pcg64::seeded(42);

    let mut table = Table::new(
        "Table 1 cross-check — measured GFLOPS on this host (CPU substrate)",
        &["Method", "N=128", "N=256", "N=384", "N=512"],
    );

    for kind in KernelKind::ALL {
        let mut cells = vec![kind.paper_name().to_string()];
        for &n in &sizes {
            let r = (n / 16).max(4);
            let cache = Arc::new(FactorCache::new(512 << 20));
            let lr_cfg = LowRankConfig {
                rank: RankStrategy::Fixed(r),
                ..Default::default()
            };
            let backend = Backend::new(None, cache, lr_cfg);
            let a = Matrix::low_rank_noisy(n, n, r, 1e-4, &mut rng);
            let b = Matrix::low_rank_noisy(n, n, r, 1e-4, &mut rng);

            // Warm rows cache factors under stable ids; LowRankFp8 stays
            // anonymous = factorizes inside the timed region (paper's
            // cold Table-1 regime).
            let ids = if kind == KernelKind::LowRankAuto {
                (Some(1u64), Some(2u64))
            } else {
                (None, None)
            };
            if kind == KernelKind::LowRankAuto {
                // Prime the cache (offline decomposition).
                backend.execute(kind, &a, &b, ids.0, ids.1).unwrap();
            }
            let m = bench(&cfg, || {
                backend.execute(kind, &a, &b, ids.0, ids.1).unwrap();
            });
            cells.push(format!("{:8.2}", m.throughput(gemm_flops(n, n, n)) / 1e9));
        }
        table.row(&cells);
    }
    table.print();
    println!("(LowRank rows use r = N/16; Auto = warm factors, FP8 = cold.)\n");
}

fn service_overhead_probe() {
    // End-to-end service throughput at one size, to quantify scheduler
    // overhead vs the raw backend (the coordinator must not be the
    // bottleneck — §Perf gate for L3).
    let cfg = config_from_env();
    let svc = GemmService::start(ServiceConfig::default()).unwrap();
    let mut rng = Pcg64::seeded(43);
    let n = 128;
    let a = Matrix::gaussian(n, n, &mut rng);
    let b = Matrix::gaussian(n, n, &mut rng);

    let inline = bench(&cfg, || {
        svc.execute_inline(&GemmRequest::new(a.clone(), b.clone())).unwrap();
    });
    let queued = bench(&cfg, || {
        svc.gemm_blocking(GemmRequest::new(a.clone(), b.clone())).unwrap();
    });
    println!(
        "service overhead @N={n}: inline {:.3} ms, queued {:.3} ms (+{:.0}%)\n",
        inline.mean_s * 1e3,
        queued.mean_s * 1e3,
        (queued.mean_s / inline.mean_s - 1.0) * 100.0
    );
}

fn main() {
    simulated_table();
    measured_table();
    service_overhead_probe();
}
