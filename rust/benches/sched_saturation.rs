//! Scheduler saturation sweep: the unified work-stealing pool under a
//! lone-fanout workload and a mixed large+small overload burst, steal on
//! vs off.
//!
//! Two scenarios per steal setting, each on a fresh service:
//!
//! - `fanout`: one shard-sized GEMM at a time on an otherwise idle pool —
//!   the latency case stealing exists for (idle siblings pull the
//!   request's tile helpers off the busy worker's deque).
//! - `mixed`: waves of 1 large + 15 small requests submitted without
//!   waiting, against a shallow admission queue — offered load exceeds
//!   capacity, so the shed counter must move, and the large requests'
//!   helpers must show steal events while the small ones keep every
//!   worker busy.
//!
//! Prints the usual bench table plus one JSON record per (scenario,
//! steal) cell so downstream tooling can diff runs:
//!
//! ```json
//! {"bench":"sched_saturation","scenario":"mixed","steal":true,
//!  "offered":128,"completed":…,"shed":…,"throughput_rps":…,
//!  "p50_ms":…,"p99_ms":…,"steal_events":…}
//! ```
//!
//! Env knobs: `LRG_BENCH_QUICK=1` shrinks sizes and wave counts.

use std::time::{Duration, Instant};

use lowrank_gemm::bench_harness::Table;
use lowrank_gemm::config::schema::SchedulerSettings;
use lowrank_gemm::coordinator::{GemmRequest, GemmService, Priority, ServiceConfig};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::{Matrix, Pcg64};

struct Shape {
    large_n: usize,
    small_n: usize,
    fanout_reqs: usize,
    mixed_waves: usize,
}

struct Outcome {
    offered: u64,
    completed: u64,
    shed: u64,
    elapsed: Duration,
    p50_ms: f64,
    p99_ms: f64,
    steal_events: u64,
}

fn service(steal: bool, queue_depth: usize) -> GemmService {
    GemmService::start(ServiceConfig {
        scheduler: SchedulerSettings {
            enabled: true,
            workers: 4,
            steal,
            queue_depth,
            ..Default::default()
        },
        ..Default::default()
    })
    .expect("service boots")
}

fn request(n: usize, rng: &mut Pcg64) -> GemmRequest {
    GemmRequest::new(Matrix::gaussian(n, n, rng), Matrix::gaussian(n, n, rng))
        .with_kernel(KernelKind::DenseF32)
}

fn percentile(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() as f64 - 1.0) * q).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

fn finish(svc: &GemmService, offered: u64, lat_ms: &mut Vec<f64>, elapsed: Duration) -> Outcome {
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let counters = svc.metrics().counters();
    Outcome {
        offered,
        completed: lat_ms.len() as u64,
        shed: counters.get("sched.shed").copied().unwrap_or(0),
        elapsed,
        p50_ms: percentile(lat_ms, 0.50),
        p99_ms: percentile(lat_ms, 0.99),
        steal_events: counters.get("sched.steal").copied().unwrap_or(0),
    }
}

/// One shard-sized GEMM at a time: latency of intra-request fan-out.
fn run_fanout(steal: bool, shape: &Shape) -> Outcome {
    let svc = service(steal, 0);
    let mut rng = Pcg64::seeded(911);
    let mut lat_ms = Vec::new();
    let t0 = Instant::now();
    for _ in 0..shape.fanout_reqs {
        let req = request(shape.large_n, &mut rng);
        let t = Instant::now();
        svc.gemm_blocking(req).expect("fanout request");
        lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    finish(&svc, shape.fanout_reqs as u64, &mut lat_ms, t0.elapsed())
}

/// Overload burst: waves of 1 large + 15 small submitted without waiting
/// against a depth-8 admission queue, priorities cycling so the watermark
/// ladder sheds (Background first) once the pool saturates.
fn run_mixed(steal: bool, shape: &Shape) -> Outcome {
    let svc = service(steal, 8);
    let mut rng = Pcg64::seeded(912);
    let mut lat_ms = Vec::new();
    let mut offered = 0u64;
    let t0 = Instant::now();
    for _ in 0..shape.mixed_waves {
        let mut wave = Vec::new();
        let mut push = |req: GemmRequest, wave: &mut Vec<(Instant, _)>| {
            offered += 1;
            if let Ok(rx) = svc.submit(req) {
                wave.push((Instant::now(), rx));
            }
        };
        push(request(shape.large_n, &mut rng), &mut wave);
        for i in 0..15 {
            let prio = match i % 3 {
                0 => Priority::Interactive,
                1 => Priority::Batch,
                _ => Priority::Background,
            };
            push(request(shape.small_n, &mut rng).with_priority(prio), &mut wave);
        }
        for (t, rx) in wave {
            if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
                lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
            }
        }
    }
    finish(&svc, offered, &mut lat_ms, t0.elapsed())
}

fn json_row(scenario: &str, steal: bool, o: &Outcome) {
    println!(
        "{{\"bench\":\"sched_saturation\",\"scenario\":\"{scenario}\",\"steal\":{steal},\
         \"offered\":{},\"completed\":{},\"shed\":{},\"throughput_rps\":{:.2},\
         \"p50_ms\":{:.3},\"p99_ms\":{:.3},\"steal_events\":{}}}",
        o.offered,
        o.completed,
        o.shed,
        o.completed as f64 / o.elapsed.as_secs_f64().max(1e-9),
        o.p50_ms,
        o.p99_ms,
        o.steal_events
    );
}

fn main() {
    let quick = std::env::var("LRG_BENCH_QUICK").is_ok();
    let shape = if quick {
        Shape {
            large_n: 512,
            small_n: 96,
            fanout_reqs: 3,
            mixed_waves: 3,
        }
    } else {
        Shape {
            large_n: 768,
            small_n: 128,
            fanout_reqs: 6,
            mixed_waves: 8,
        }
    };

    let mut table = Table::new(
        "Scheduler saturation — fanout latency and mixed overload, steal on vs off",
        &[
            "scenario", "steal", "offered", "completed", "shed", "req/s", "p50 ms", "p99 ms",
            "steals",
        ],
    );
    for steal in [true, false] {
        for (name, outcome) in [
            ("fanout", run_fanout(steal, &shape)),
            ("mixed", run_mixed(steal, &shape)),
        ] {
            table.row(&[
                name.into(),
                steal.to_string(),
                outcome.offered.to_string(),
                outcome.completed.to_string(),
                outcome.shed.to_string(),
                format!(
                    "{:8.2}",
                    outcome.completed as f64 / outcome.elapsed.as_secs_f64().max(1e-9)
                ),
                format!("{:8.3}", outcome.p50_ms),
                format!("{:8.3}", outcome.p99_ms),
                outcome.steal_events.to_string(),
            ]);
            json_row(name, steal, &outcome);
        }
    }
    table.print();
    println!(
        "\n(acceptance: with steal=true the mixed scenario must show ≥ 1 steal event and \
         a non-zero shed count — offered load exceeds the depth-8 admission queue; \
         steal=false is the control arm: same pool, no cross-worker stealing, 0 steals)"
    );
}
