//! Accuracy-probe overhead and agreement — the accuracy-plane instrument.
//!
//! Three questions, answered with numbers:
//!
//! 1. What does probing cost a request? End-to-end `gemm_blocking`
//!    latency with `[accuracy]` off, sampling 1-in-16 (the default-shaped
//!    deployment) and sampling every request. Probes ride the shard
//!    pool behind serving work, so the visible cost is the sampled
//!    operand clone — at 1/16 it must sit within run-to-run noise.
//! 2. What does one probe cost in isolation? `probe_rel_error` wall time
//!    across sizes, against its O((mn + mk + kn)·s) matvec bound.
//! 3. Does the estimator agree with ground truth? Measured vs probed
//!    relative error on seeded-spectrum truncations, with the ratio in
//!    each JSON row for CI to gate on.
//!
//! Every measurement prints one JSON record
//! (`{"bench":"accuracy_probes","case":…}`) for CI's bench-smoke
//! artifact collection, same shape as `telemetry_overhead`.

use lowrank_gemm::accuracy::probe_rel_error;
use lowrank_gemm::bench_harness::{bench, config_from_env, Measurement, Table};
use lowrank_gemm::config::AccuracySettings;
use lowrank_gemm::coordinator::{GemmRequest, GemmService, ServiceConfig};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::svd::truncated_svd;
use lowrank_gemm::linalg::{Matrix, Pcg64};

fn json_row(case: &str, n: usize, m: &Measurement) {
    println!(
        "{{\"bench\":\"accuracy_probes\",\"case\":\"{case}\",\"n\":{n},\
         \"mean_s\":{:.6e},\"min_s\":{:.6e},\"max_s\":{:.6e},\"stddev_s\":{:.6e},\
         \"iters\":{}}}",
        m.mean_s, m.min_s, m.max_s, m.stddev_s, m.iters
    );
}

fn probed_request_latency() {
    let cfg = config_from_env();
    let n = 256;
    let mut rng = Pcg64::seeded(81);
    let a = Matrix::gaussian(n, n, &mut rng);
    let b = Matrix::gaussian(n, n, &mut rng);

    let run = |accuracy: AccuracySettings| {
        let svc = GemmService::start(ServiceConfig {
            accuracy,
            ..Default::default()
        })
        .unwrap();
        let m = bench(&cfg, || {
            svc.gemm_blocking(
                GemmRequest::new(a.clone(), b.clone()).with_kernel(KernelKind::DenseF32),
            )
            .unwrap();
        });
        svc.drain();
        m
    };
    let off = run(AccuracySettings::default());
    let sparse = run(AccuracySettings {
        enabled: true,
        sample_every: 16,
        probes: 8,
        ..Default::default()
    });
    let dense = run(AccuracySettings {
        enabled: true,
        sample_every: 1,
        probes: 8,
        ..Default::default()
    });

    let mut table = Table::new(
        "Request latency vs probe sampling rate [us]",
        &["N", "unprobed", "1-in-16", "every req"],
    );
    table.row(&[
        n.to_string(),
        format!("{:8.1}", off.mean_s * 1e6),
        format!(
            "{:8.1} ({:+5.2}%)",
            sparse.mean_s * 1e6,
            (sparse.mean_s / off.mean_s - 1.0) * 100.0
        ),
        format!(
            "{:8.1} ({:+5.2}%)",
            dense.mean_s * 1e6,
            (dense.mean_s / off.mean_s - 1.0) * 100.0
        ),
    ]);
    table.print();
    println!();
    json_row("request_unprobed", n, &off);
    json_row("request_probed_1_16", n, &sparse);
    json_row("request_probed_1_1", n, &dense);
}

fn probe_cost_direct() {
    let cfg = config_from_env();
    let mut table = Table::new(
        "probe_rel_error cost, s=8 probe vectors [us]",
        &["N", "mean", "per probe"],
    );
    for n in [128usize, 256, 512] {
        let mut rng = Pcg64::seeded(82 + n as u64);
        let a = Matrix::gaussian(n, n, &mut rng);
        let b = Matrix::gaussian(n, n, &mut rng);
        let c = a.matmul(&b);
        let m = bench(&cfg, || {
            probe_rel_error(&a, &b, &c, 8, 4242).unwrap();
        });
        table.row(&[
            n.to_string(),
            format!("{:8.1}", m.mean_s * 1e6),
            format!("{:8.2}", m.mean_s * 1e6 / 8.0),
        ]);
        json_row("probe_direct", n, &m);
    }
    table.print();
    println!();
}

fn estimator_agreement() {
    let mut rng = Pcg64::seeded(83);
    let sv: Vec<f32> = (0..16).map(|i| 0.6f32.powi(i)).collect();
    let mut table = Table::new(
        "Estimator vs measured relative error (rank-r truncations)",
        &["N", "rank", "measured", "estimated", "ratio"],
    );
    for (n, r) in [(128usize, 4usize), (256, 8), (384, 12)] {
        let a = Matrix::with_spectrum(n, n, &sv, &mut rng);
        let b = Matrix::gaussian(n, n, &mut rng);
        let exact = a.matmul(&b);
        let served = truncated_svd(&a, r).unwrap().reconstruct().matmul(&b);
        let measured = served.rel_frobenius_distance(&exact) as f64;
        let estimated = probe_rel_error(&a, &b, &served, 8, (n + r) as u64).unwrap();
        let ratio = estimated / measured;
        table.row(&[
            n.to_string(),
            r.to_string(),
            format!("{measured:10.3e}"),
            format!("{estimated:10.3e}"),
            format!("{ratio:6.3}"),
        ]);
        println!(
            "{{\"bench\":\"accuracy_probes\",\"case\":\"agreement\",\"n\":{n},\"rank\":{r},\
             \"measured\":{measured:.6e},\"estimated\":{estimated:.6e},\"ratio\":{ratio:.4}}}"
        );
    }
    table.print();
    println!();
}

fn main() {
    probed_request_latency();
    probe_cost_direct();
    estimator_agreement();
}
