//! Factor-cache amortization sweep — the `[cache]` plane's instrument.
//!
//! For each size, measures the three regimes the router's cost model
//! prices: the **cold** low-rank path (rSVD both operands + factor
//! chain), the **warm** path (content-cache hit + factor chain), and the
//! **dense** baseline — plus the cache's own lookup overhead, which must
//! stay negligible against any of them. The amortization claim is the
//! ratio: cold pays the decomposition once, every further request runs
//! at warm speed.
//!
//! Prints the usual bench table plus one JSON record per measurement:
//!
//! ```json
//! {"bench":"cache_amortization","path":"warm","n":512,
//!  "mean_s":…,"min_s":…,"max_s":…,"stddev_s":…,"iters":5,
//!  "speedup_vs_cold":…}
//! ```
//!
//! Env knobs: `LRG_BENCH_QUICK=1` shrinks sizes and iterations;
//! `LRG_BENCH_MAXN=<n>` caps the sweep.

use lowrank_gemm::bench_harness::{bench, config_from_env, Measurement, Table};
use lowrank_gemm::cache::{ContentCache, Fingerprint};
use lowrank_gemm::fp8::StorageFormat;
use lowrank_gemm::linalg::{gemm_blocked, Matrix, Pcg64};
use lowrank_gemm::lowrank::{factorize, lowrank_matmul, LowRankConfig, RankStrategy};

fn json_row(path: &str, n: usize, m: &Measurement, speedup_vs_cold: f64) {
    println!(
        "{{\"bench\":\"cache_amortization\",\"path\":\"{path}\",\"n\":{n},\
         \"mean_s\":{:.6e},\"min_s\":{:.6e},\"max_s\":{:.6e},\"stddev_s\":{:.6e},\
         \"iters\":{},\"speedup_vs_cold\":{:.3}}}",
        m.mean_s, m.min_s, m.max_s, m.stddev_s, m.iters, speedup_vs_cold
    );
}

fn main() {
    let cfg = config_from_env();
    let quick = std::env::var("LRG_BENCH_QUICK").is_ok();
    let max_n: usize = std::env::var("LRG_BENCH_MAXN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX);
    let sizes: Vec<usize> = if quick {
        vec![96, 128, 192]
    } else {
        vec![256, 384, 512, 768]
    };
    let sizes: Vec<usize> = sizes.into_iter().filter(|&n| n <= max_n).collect();

    let mut table = Table::new(
        "Factor-cache amortization — cold (rSVD + chain) vs warm (hit + chain) vs dense",
        &["N", "cold ms", "warm ms", "dense ms", "cold/warm", "lookup us"],
    );

    for &n in &sizes {
        let r = (n / 16).max(4);
        let mut rng = Pcg64::seeded(9090);
        let a = Matrix::low_rank_noisy(n, n, r, 1e-4, &mut rng);
        let b = Matrix::low_rank_noisy(n, n, r, 1e-4, &mut rng);
        let lr_cfg = LowRankConfig {
            rank: RankStrategy::Fixed(r),
            storage: StorageFormat::F32,
            ..Default::default()
        };

        // Cold regime: both decompositions inside the timed region — the
        // cost every request pays without the cache plane.
        let cold = bench(&cfg, || {
            let fa = factorize(&a, &lr_cfg).unwrap();
            let fb = factorize(&b, &lr_cfg).unwrap();
            lowrank_matmul(&fa, &fb);
        });

        // Warm regime: factors served out of the content cache, exactly
        // the serving hot path after the first request.
        let cache = ContentCache::new(256 << 20, 1);
        let (fp_a, fp_b) = (Fingerprint::of(&a), Fingerprint::of(&b));
        cache.put(fp_a, factorize(&a, &lr_cfg).unwrap());
        cache.put(fp_b, factorize(&b, &lr_cfg).unwrap());
        let warm = bench(&cfg, || {
            let fa = cache.get(fp_a).unwrap();
            let fb = cache.get(fp_b).unwrap();
            lowrank_matmul(&fa, &fb);
        });

        // Dense baseline.
        let dense = bench(&cfg, || {
            gemm_blocked(&a, &b).unwrap();
        });

        // Pure lookup overhead (hit + clone, no chain).
        let lookup = bench(&cfg, || {
            std::hint::black_box(cache.get(fp_a));
        });

        let speedup = cold.mean_s / warm.mean_s;
        table.row(&[
            n.to_string(),
            format!("{:9.2}", cold.mean_s * 1e3),
            format!("{:9.2}", warm.mean_s * 1e3),
            format!("{:9.2}", dense.mean_s * 1e3),
            format!("{speedup:5.2}x"),
            format!("{:7.1}", lookup.mean_s * 1e6),
        ]);
        json_row("cold", n, &cold, 1.0);
        json_row("warm", n, &warm, speedup);
        json_row("dense", n, &dense, cold.mean_s / dense.mean_s);
        json_row("lookup", n, &lookup, cold.mean_s / lookup.mean_s);
    }
    table.print();
    println!(
        "\n(acceptance: warm must beat cold at every N — the gap is the \
         per-request decomposition cost the cache plane amortizes away)"
    );
}
