//! Figure 1 reproduction: time-to-solution, throughput, relative error and
//! speedup vs matrix size (√2-geometric sweep, log₂ axis) for all five
//! methods.
//!
//! Three blocks:
//!   1. simulated series at paper scale (N = 1024 … 20480) — regenerates
//!      the four panels of Fig. 1 as CSV-ish rows,
//!   2. measured series on this host (N = 64 … 1024) — the *real*
//!      dense-vs-lowrank crossover on the CPU substrate (O(n³) vs O(n²r)),
//!   3. measured relative-error series (the error panel is measured, not
//!      simulated — numerics are real on every substrate).

use lowrank_gemm::bench_harness::{bench, config_from_env, Table};
use lowrank_gemm::coordinator::{Backend, GemmRequest};
use lowrank_gemm::gpu_sim::{DeviceProfile, Roofline, SimResult};
use lowrank_gemm::kernels::KernelKind;
use lowrank_gemm::linalg::{gemm_flops, Matrix, Pcg64};
use lowrank_gemm::lowrank::{FactorCache, LowRankConfig, RankStrategy};
use lowrank_gemm::trace::sqrt2_sweep;
use std::sync::Arc;

fn paper_rank(n: usize) -> usize {
    (n / 40).max(16)
}

fn sim_row(rl: &Roofline, kind: KernelKind, n: usize) -> SimResult {
    let r = paper_rank(n);
    match kind {
        KernelKind::DenseF32 => rl.pytorch_f32(n),
        KernelKind::DenseF16 => rl.torchcompile_f16(n),
        KernelKind::DenseFp8 => rl.cublas_fp8(n),
        KernelKind::LowRankFp8 => rl.lowrank_fp8(n, r),
        KernelKind::LowRankAuto => rl.lowrank_auto(n, r),
    }
}

fn simulated_panels() {
    let rl = Roofline::new(DeviceProfile::rtx4090());
    let sweep = sqrt2_sweep(1024, 20480);

    let mut table = Table::new(
        "Fig 1 (simulated, RTX 4090) — time [ms] / TFLOPS / speedup-vs-f32 per N",
        &["N", "f32", "f16", "fp8", "lr_fp8", "lr_auto", "winner"],
    );
    let mut crossover = None;
    for &n in &sweep {
        let sims: Vec<(KernelKind, SimResult)> = KernelKind::ALL
            .iter()
            .map(|&k| (k, sim_row(&rl, k, n)))
            .collect();
        let f32_time = sims[0].1.time_s;
        let winner = sims
            .iter()
            .min_by(|a, b| a.1.time_s.partial_cmp(&b.1.time_s).unwrap())
            .unwrap()
            .0;
        if winner.is_lowrank() && crossover.is_none() {
            crossover = Some(n);
        }
        let cell = |s: &SimResult| {
            format!("{:.1}/{:.0}/{:.1}", s.time_s * 1e3, s.tflops, f32_time / s.time_s)
        };
        table.row(&[
            n.to_string(),
            cell(&sims[0].1),
            cell(&sims[1].1),
            cell(&sims[2].1),
            cell(&sims[3].1),
            cell(&sims[4].1),
            winner.id().to_string(),
        ]);
    }
    table.print();
    println!(
        "simulated crossover (low-rank first wins): N = {} (paper: ~10240)\n",
        crossover.map(|n| n.to_string()).unwrap_or_else(|| "none".into())
    );
}

fn measured_crossover() {
    // Real times on this host. Dense is O(n³); warm low-rank is O(n²r).
    // With r = n/16 the asymptotic ratio is 16/2 = 8x fewer flops, so the
    // crossover happens where factor-chain overheads are amortized —
    // genuinely measurable on the CPU substrate.
    let cfg = config_from_env();
    let mut rng = Pcg64::seeded(99);
    let mut table = Table::new(
        "Fig 1 (measured, this host) — dense f32 vs warm low-rank [ms]",
        &["N", "dense", "lowrank(warm)", "speedup", "rel err"],
    );
    let mut crossover = None;
    for n in sqrt2_sweep(64, 1024) {
        let r = (n / 16).max(2);
        let a = Matrix::low_rank_noisy(n, n, r, 1e-4, &mut rng);
        let b = Matrix::low_rank_noisy(n, n, r, 1e-4, &mut rng);
        let cache = Arc::new(FactorCache::new(512 << 20));
        let backend = Backend::new(
            None,
            cache,
            LowRankConfig {
                rank: RankStrategy::Fixed(r),
                ..Default::default()
            },
        );
        // Warm the factor cache (offline decomposition).
        backend
            .execute(KernelKind::LowRankAuto, &a, &b, Some(1), Some(2))
            .unwrap();

        let dense = bench(&cfg, || {
            backend.execute(KernelKind::DenseF32, &a, &b, None, None).unwrap();
        });
        let lowrank = bench(&cfg, || {
            backend
                .execute(KernelKind::LowRankAuto, &a, &b, Some(1), Some(2))
                .unwrap();
        });
        let out = backend
            .execute(KernelKind::LowRankAuto, &a, &b, Some(1), Some(2))
            .unwrap();
        let err = out.c.rel_frobenius_distance(&a.matmul(&b));
        let speedup = dense.mean_s / lowrank.mean_s;
        if speedup > 1.0 && crossover.is_none() {
            crossover = Some(n);
        }
        table.row(&[
            n.to_string(),
            format!("{:8.2}", dense.mean_s * 1e3),
            format!("{:8.2}", lowrank.mean_s * 1e3),
            format!("{speedup:6.2}x"),
            format!("{err:.2e}"),
        ]);
    }
    table.print();
    println!(
        "measured crossover on this host: N = {} (shape matches Fig 1; scale shifts with the substrate)\n",
        crossover.map(|n| n.to_string()).unwrap_or_else(|| ">1024".into())
    );
}

fn measured_error_panel() {
    // Fig 1's error panel: mean relative error per method vs N — measured
    // with real numerics (fp8 codecs + truncation), not simulated.
    let mut rng = Pcg64::seeded(100);
    let mut table = Table::new(
        "Fig 1 error panel (measured) — relative error per method",
        &["N", "f32", "f16", "fp8", "lr_fp8", "lr_auto"],
    );
    for n in [128usize, 256, 512] {
        let r = (n / 16).max(2);
        let a = Matrix::low_rank_noisy(n, n, r, 1e-3, &mut rng);
        let b = Matrix::low_rank_noisy(n, n, r, 1e-3, &mut rng);
        let exact = a.matmul(&b);
        let cache = Arc::new(FactorCache::new(512 << 20));
        let backend = Backend::new(
            None,
            cache,
            LowRankConfig {
                rank: RankStrategy::Fixed(r),
                ..Default::default()
            },
        );
        let mut cells = vec![n.to_string()];
        for kind in KernelKind::ALL {
            let out = backend.execute(kind, &a, &b, Some(1), Some(2)).unwrap();
            cells.push(format!("{:.2e}", out.c.rel_frobenius_distance(&exact)));
        }
        table.row(&cells);
    }
    table.print();
    println!("(paper §5.4: dense <0.01%, low-rank 1-2% — same bands.)\n");
}

fn main() {
    simulated_panels();
    measured_crossover();
    measured_error_panel();
    // Keep the coordinator types exercised so the bench doubles as a
    // smoke test of the public API.
    let _ = GemmRequest::new(Matrix::zeros(2, 2), Matrix::zeros(2, 2));
    let _ = gemm_flops(2, 2, 2);
}
