//! Autotune convergence sweep: samples-to-convergence of the calibrated
//! selector across size classes × skew factors, plus the record/lookup
//! overhead the plane adds to the serving path.
//!
//! For each (N, skew) point the analytically-best kernel is given a
//! synthetic measured slowdown of `skew`× while every other kernel
//! behaves exactly as modeled; the sweep counts how many per-kernel
//! sample rounds the confidence blend needs before the selector's
//! ranking flips away from the mispredicted kernel.
//!
//! Prints the usual bench table plus one JSON record per sweep point
//! (same style as `shard_scaling.rs`) so downstream tooling can diff
//! runs:
//!
//! ```json
//! {"bench":"autotune_convergence","n":4096,"size_class":12,"skew":10.0,
//!  "alpha":0.2,"min_samples":5,"samples_to_flip":2,"converged":true,
//!  "from":"lowrank_auto","to":"lowrank_fp8"}
//! ```
//!
//! Env knobs: `LRG_BENCH_QUICK=1` shrinks the sweep;
//! `LRG_BENCH_MAXN=<n>` caps the size axis.

use std::sync::Arc;

use lowrank_gemm::autotune::CalibrationTable;
use lowrank_gemm::bench_harness::{bench, config_from_env, Table};
use lowrank_gemm::coordinator::BucketKey;
use lowrank_gemm::gpu_sim::DeviceProfile;
use lowrank_gemm::kernels::{AutoKernelSelector, KernelKind, SelectorInputs};

const MAX_ROUNDS: usize = 500;
const ALPHA: f64 = 0.2;
const MIN_SAMPLES: u64 = 5;

fn inputs(n: usize) -> SelectorInputs {
    SelectorInputs {
        m: n,
        k: n,
        n,
        error_tolerance: 0.05,
        rank: (n / 40).max(16),
        factors_cached: true,
        factored_output_ok: true,
        decomp_amortization: 1.0,
        fp8_reencode: false,
    }
}

// The table's actual cell key (kernel-independent for square shapes), so
// the JSON rows always describe the cells the sweep populates.
fn size_class(n: usize) -> u32 {
    BucketKey::of(KernelKind::DenseF32, n, n, n).size_class
}

struct FlipResult {
    rounds: usize,
    converged: bool,
    from: KernelKind,
    to: KernelKind,
}

/// Rounds of per-kernel samples until the selector abandons the skewed
/// kernel (each round feeds one measured sample per ranked kernel, the
/// ε-greedy policy's steady state).
fn samples_to_flip(n: usize, skew: f64) -> FlipResult {
    let table = Arc::new(CalibrationTable::new(ALPHA, MIN_SAMPLES));
    let selector =
        AutoKernelSelector::new(DeviceProfile::rtx4090()).with_calibration(table.clone());
    let inp = inputs(n);
    let baseline = selector.select(&inp).kind;
    for round in 1..=MAX_ROUNDS {
        for c in selector.ranked(&inp) {
            let raw = c.cost.time_s / c.calibration;
            let observed = if c.kind == baseline { raw * skew } else { raw };
            table.record(c.kind, inp.m, inp.k, inp.n, raw, observed);
        }
        let now = selector.select(&inp).kind;
        if now != baseline {
            return FlipResult {
                rounds: round,
                converged: true,
                from: baseline,
                to: now,
            };
        }
    }
    FlipResult {
        rounds: MAX_ROUNDS,
        converged: false,
        from: baseline,
        to: baseline,
    }
}

fn main() {
    let cfg = config_from_env();
    let quick = std::env::var("LRG_BENCH_QUICK").is_ok();
    let max_n: usize = std::env::var("LRG_BENCH_MAXN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX);
    let sizes: Vec<usize> = if quick {
        vec![512, 1024, 2048]
    } else {
        vec![1024, 2048, 4096, 8192, 20480]
    };
    let sizes: Vec<usize> = sizes.into_iter().filter(|&n| n <= max_n).collect();
    let skews: &[f64] = if quick {
        &[3.0, 10.0]
    } else {
        &[1.5, 3.0, 10.0, 50.0]
    };

    let mut table = Table::new(
        "Autotune convergence — sample rounds until the calibrated selector flips",
        &["N", "class", "skew", "rounds", "converged", "from -> to"],
    );

    for &n in &sizes {
        for &skew in skews {
            let r = samples_to_flip(n, skew);
            table.row(&[
                n.to_string(),
                size_class(n).to_string(),
                format!("{skew:.1}x"),
                r.rounds.to_string(),
                r.converged.to_string(),
                format!("{} -> {}", r.from.id(), r.to.id()),
            ]);
            println!(
                "{{\"bench\":\"autotune_convergence\",\"n\":{n},\"size_class\":{},\
                 \"skew\":{skew},\"alpha\":{ALPHA},\"min_samples\":{MIN_SAMPLES},\
                 \"samples_to_flip\":{},\"converged\":{},\"from\":\"{}\",\"to\":\"{}\"}}",
                size_class(n),
                r.rounds,
                r.converged,
                r.from.id(),
                r.to.id()
            );
        }
    }
    table.print();

    // Serving-path overhead of the plane: one record() and one
    // correction() per request, on a table populated across every
    // kernel × the sweep's size classes.
    let t = CalibrationTable::new(ALPHA, MIN_SAMPLES);
    for &n in &sizes {
        for kind in KernelKind::ALL {
            t.record(kind, n, n, n, 1.0e-3, 1.5e-3);
        }
    }
    let rec = bench(&cfg, || {
        t.record(KernelKind::DenseF32, 4096, 4096, 4096, 1.0e-3, 1.2e-3);
    });
    let look = bench(&cfg, || {
        std::hint::black_box(t.correction(KernelKind::DenseF32, 4096, 4096, 4096));
    });
    println!(
        "{{\"bench\":\"autotune_overhead\",\"op\":\"record\",\"mean_s\":{:.6e},\"iters\":{}}}",
        rec.mean_s, rec.iters
    );
    println!(
        "{{\"bench\":\"autotune_overhead\",\"op\":\"correction\",\"mean_s\":{:.6e},\"iters\":{}}}",
        look.mean_s, look.iters
    );
    println!(
        "\n(acceptance: every skew ≥ 3x converges within tens of rounds, and \
         record/correction overhead stays in the tens of nanoseconds — noise \
         next to any GEMM the selector routes)"
    );
}
