//! Table 2 reproduction: GPU memory + TFLOPS at maximum scale (N=20480),
//! and the §5.3/§5.5 memory-accounting walkthrough, audited.
//!
//! Memory comes from two independent places that must agree:
//! the roofline pipelines' `peak_memory_bytes` (model) and a
//! `MemoryTracker` replay of each pipeline's allocations (the simulated
//! device allocator the serving system uses for admission control).

use lowrank_gemm::bench_harness::Table;
use lowrank_gemm::fp8::StorageFormat;
use lowrank_gemm::gpu_sim::{DeviceProfile, MemoryTracker, Roofline};
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::lowrank::{factorize, LowRankConfig, RankStrategy};

const N: usize = 20480;
const R: usize = 512; // paper §5.5 worked example

/// Paper Table 2, verbatim.
const PAPER: [(&str, f64, f64, f64); 5] = [
    // (method, memory GB, memory %, TFLOPS)
    ("PyTorch FP32", 15.0, 60.0, 49.0),
    ("TorchCompile FP16", 7.5, 30.0, 139.0),
    ("cuBLAS Optimized FP8", 7.5, 30.0, 137.0),
    ("LowRank FP8", 3.75, 15.0, 209.0),
    ("LowRank Auto", 3.75, 15.0, 378.0),
];

fn replay_memory(method: &str, tracker: &mut MemoryTracker) {
    let nn = (N * N) as u64;
    let nr = (N * R) as u64;
    match method {
        // Dense: A, B, C at storage width (+ workspace factor folded into
        // the pipelines' overhead_factor; tracker carries raw tensors).
        "PyTorch FP32" => {
            for (name, b) in [("A", nn * 4), ("B", nn * 4), ("C", nn * 4)] {
                // Paper charges ~5 GB/matrix incl. temporaries (§5.5);
                // raw is 1.68 GB — we track raw + a workspace block.
                tracker.alloc(name, b).unwrap();
            }
            tracker.alloc("workspace", 3 * nn * 4 * 2 / 3).unwrap();
        }
        "TorchCompile FP16" | "cuBLAS Optimized FP8" => {
            let w = if method.contains("FP16") { 2 } else { 2 /* fp8 stored, f16 staged */ };
            for (name, b) in [("A", nn * w), ("B", nn * w), ("C", nn * w)] {
                tracker.alloc(name, b).unwrap();
            }
            tracker.alloc("workspace", nn * w).unwrap();
        }
        "LowRank FP8" | "LowRank Auto" => {
            // Factored operands: U, s, Vᵀ per matrix at 1 B/elem + dense C
            // only for the materializing variant.
            for m in ["A", "B"] {
                tracker.alloc(&format!("{m}.U"), nr).unwrap();
                tracker.alloc(&format!("{m}.s"), (R * 4) as u64).unwrap();
                tracker.alloc(&format!("{m}.Vt"), nr).unwrap();
            }
            if method == "LowRank FP8" {
                tracker.alloc("C", nn).unwrap();
            } else {
                tracker.alloc("C.U", nr).unwrap();
                tracker.alloc("C.Vt", nr).unwrap();
            }
            tracker.alloc("decomp workspace", 8 * nr).unwrap();
        }
        _ => unreachable!(),
    }
}

fn main() {
    let device = DeviceProfile::rtx4090();
    let rl = Roofline::new(device.clone());

    let mut table = Table::new(
        "Table 2 — memory + TFLOPS at N=20480 (model | paper)",
        &["Method", "Mem (model)", "Mem (paper)", "Mem %", "TFLOPS (model|paper)"],
    );

    for (method, p_gb, p_pct, p_tf) in PAPER {
        let sim = match method {
            "PyTorch FP32" => rl.pytorch_f32(N),
            "TorchCompile FP16" => rl.torchcompile_f16(N),
            "cuBLAS Optimized FP8" => rl.cublas_fp8(N),
            "LowRank FP8" => rl.lowrank_fp8(N, R),
            "LowRank Auto" => rl.lowrank_auto(N, R),
            _ => unreachable!(),
        };
        let mut tracker = MemoryTracker::new(device.memory_bytes);
        replay_memory(method, &mut tracker);
        let gb = tracker.peak() as f64 / 1e9;
        table.row(&[
            method.to_string(),
            format!("{:5.2} GB", gb),
            format!("{p_gb:5.2} GB"),
            format!("{:4.1}% | {p_pct:4.1}%", 100.0 * tracker.peak_fraction()),
            format!("{:6.1} | {p_tf:6.1}", sim.tflops),
        ]);
        // The two accounting paths must agree on the order of magnitude.
        let model_gb = sim.peak_memory_bytes / 1e9;
        assert!(
            (model_gb / gb).max(gb / model_gb) < 6.0,
            "{method}: model {model_gb:.2} GB vs tracker {gb:.2} GB diverge"
        );
    }
    table.print();

    // §5.5 worked example, audited with the real factor implementation.
    println!("\n§5.5 audit (factorized storage at N=20480, r=512, FP8):");
    let elems = N * R + R + R * N;
    println!(
        "  factor elements = {elems} ({:.2} M; paper says ~20.99 M)",
        elems as f64 / 1e6
    );
    let mut rng = Pcg64::seeded(7);
    // Same arithmetic at measurable scale via the real LowRankFactor.
    let small_n = 1024;
    let small_r = small_n / 40;
    let a = Matrix::low_rank_noisy(small_n, small_n, small_r, 1e-4, &mut rng);
    let f = factorize(
        &a,
        &LowRankConfig {
            rank: RankStrategy::Fixed(small_r),
            storage: StorageFormat::Fp8(lowrank_gemm::fp8::Fp8Format::E4M3),
            ..Default::default()
        },
    )
    .unwrap();
    println!(
        "  measured at N={small_n}, r={small_r}: factored {} KiB vs dense-fp8 {} KiB -> {:.1}% saving",
        f.storage_bytes() / 1024,
        f.dense_bytes() / 1024,
        100.0 * f.memory_saving()
    );
    println!(
        "  paper's headline: 75% vs FP32 dense ({} KiB) -> {:.1}% saving",
        small_n * small_n * 4 / 1024,
        100.0 * (1.0 - f.storage_bytes() as f64 / (small_n * small_n * 4) as f64)
    );
    println!(
        "  effective capacity expansion: {:.2}x (paper: 3.25x-4x)",
        (small_n * small_n * 4) as f64 / f.storage_bytes() as f64
    );
}
