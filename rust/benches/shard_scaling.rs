//! Shard-scaling sweep: workers ∈ {1, 2, 4, 8} × N ∈ {1024, 4096, 8192}
//! for the dense and low-rank sharded paths, against the single-threaded
//! kernels as baseline.
//!
//! Prints the usual bench table plus one JSON record per measurement
//! (same measurement shape as `bench_harness::Measurement`, tagged with
//! the sweep point) so downstream tooling can diff runs:
//!
//! ```json
//! {"bench":"shard_scaling","path":"dense","n":4096,"workers":4,
//!  "mean_s":…,"min_s":…,"max_s":…,"stddev_s":…,"iters":5,
//!  "gflops":…,"speedup_vs_serial":…}
//! ```
//!
//! Env knobs: `LRG_BENCH_QUICK=1` shrinks sizes and iterations;
//! `LRG_BENCH_MAXN=<n>` caps the sweep (dense 8192³ is ~1.1 TFLOP per
//! iteration on the CPU substrate — budget accordingly).

use lowrank_gemm::bench_harness::{bench, config_from_env, BenchConfig, Measurement, Table};
use lowrank_gemm::fp8::StorageFormat;
use lowrank_gemm::linalg::gemm::gemm_flops;
use lowrank_gemm::linalg::{gemm_blocked, Matrix, Pcg64};
use lowrank_gemm::lowrank::gemm::lowrank_flops;
use lowrank_gemm::lowrank::{lowrank_matmul, LowRankConfig, RankStrategy};
use lowrank_gemm::shard::{factorize_sharded, ShardExecutor, ShardPlan, TileGrid};

const WORKER_SWEEP: [usize; 4] = [1, 2, 4, 8];

fn executor(workers: usize) -> ShardExecutor {
    ShardExecutor::new(ShardPlan {
        grid: TileGrid::default(),
        workers,
        min_parallel_n: 256,
    })
}

fn json_row(path: &str, n: usize, workers: usize, m: &Measurement, flops: f64, speedup: f64) {
    println!(
        "{{\"bench\":\"shard_scaling\",\"path\":\"{path}\",\"n\":{n},\"workers\":{workers},\
         \"mean_s\":{:.6e},\"min_s\":{:.6e},\"max_s\":{:.6e},\"stddev_s\":{:.6e},\
         \"iters\":{},\"gflops\":{:.2},\"speedup_vs_serial\":{:.3}}}",
        m.mean_s,
        m.min_s,
        m.max_s,
        m.stddev_s,
        m.iters,
        flops / m.mean_s / 1e9,
        speedup
    );
}

fn main() {
    let base_cfg = config_from_env();
    let quick = std::env::var("LRG_BENCH_QUICK").is_ok();
    let max_n: usize = std::env::var("LRG_BENCH_MAXN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX);
    let sizes: Vec<usize> = if quick {
        vec![256, 512, 1024]
    } else {
        vec![1024, 4096, 8192]
    };
    let sizes: Vec<usize> = sizes.into_iter().filter(|&n| n <= max_n).collect();

    let mut table = Table::new(
        "Shard scaling — sharded vs single-threaded (dense f32 / warm low-rank chain)",
        &["path", "N", "workers", "mean ms", "GFLOPS", "speedup vs serial"],
    );

    for &n in &sizes {
        // Large sizes: trim iterations — each dense iteration is 2·N³ FLOPs.
        let cfg = if n >= 4096 {
            BenchConfig {
                warmup_iters: 1,
                measure_iters: base_cfg.measure_iters.min(2),
            }
        } else {
            base_cfg
        };

        let mut rng = Pcg64::seeded(4242);
        let r = (n / 16).max(16);
        let a = Matrix::low_rank_noisy(n, n, r, 1e-4, &mut rng);
        let b = Matrix::low_rank_noisy(n, n, r, 1e-4, &mut rng);
        let dense_flops = gemm_flops(n, n, n);
        let lr_flops = lowrank_flops(n, n, n, r, r);

        // Offline factorization (not timed) for the warm chain path.
        let fcfg = LowRankConfig {
            rank: RankStrategy::Fixed(r),
            storage: StorageFormat::F32,
            ..Default::default()
        };
        let warm = executor(4);
        let fa = factorize_sharded(&warm, &a, &fcfg).expect("factorize A");
        let fb = factorize_sharded(&warm, &b, &fcfg).expect("factorize B");
        drop(warm);

        // Single-threaded baselines.
        let dense_serial = bench(&cfg, || {
            gemm_blocked(&a, &b).unwrap();
        });
        let lr_serial = bench(&cfg, || {
            lowrank_matmul(&fa, &fb);
        });
        table.row(&[
            "dense-serial".into(),
            n.to_string(),
            "-".into(),
            format!("{:10.2}", dense_serial.mean_s * 1e3),
            format!("{:8.2}", dense_flops / dense_serial.mean_s / 1e9),
            "1.00x".into(),
        ]);
        json_row("dense-serial", n, 0, &dense_serial, dense_flops, 1.0);
        table.row(&[
            "lowrank-serial".into(),
            n.to_string(),
            "-".into(),
            format!("{:10.2}", lr_serial.mean_s * 1e3),
            format!("{:8.2}", lr_flops / lr_serial.mean_s / 1e9),
            "1.00x".into(),
        ]);
        json_row("lowrank-serial", n, 0, &lr_serial, lr_flops, 1.0);

        for &workers in &WORKER_SWEEP {
            let ex = executor(workers);
            let dense = bench(&cfg, || {
                ex.gemm(&a, &b).unwrap();
            });
            let dsp = dense_serial.mean_s / dense.mean_s;
            table.row(&[
                "dense".into(),
                n.to_string(),
                workers.to_string(),
                format!("{:10.2}", dense.mean_s * 1e3),
                format!("{:8.2}", dense_flops / dense.mean_s / 1e9),
                format!("{dsp:5.2}x"),
            ]);
            json_row("dense", n, workers, &dense, dense_flops, dsp);

            let lr = bench(&cfg, || {
                ex.lowrank_matmul(&fa, &fb).unwrap();
            });
            let lsp = lr_serial.mean_s / lr.mean_s;
            table.row(&[
                "lowrank".into(),
                n.to_string(),
                workers.to_string(),
                format!("{:10.2}", lr.mean_s * 1e3),
                format!("{:8.2}", lr_flops / lr.mean_s / 1e9),
                format!("{lsp:5.2}x"),
            ]);
            json_row("lowrank", n, workers, &lr, lr_flops, lsp);
        }
    }
    table.print();
    println!(
        "\n(acceptance: dense N=4096 workers=4 should show ≥ 2x speedup vs serial \
         on a ≥ 4-core host)"
    );
}
