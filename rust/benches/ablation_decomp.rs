//! Ablation A1: decomposition method (exact SVD vs randomized SVD vs
//! Lanczos) — cost and quality across sizes and spectra (paper §3.1's
//! "SVD, randomized SVD" method choice, which the auto selector makes).

use lowrank_gemm::bench_harness::{bench, config_from_env, Table};
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::lowrank::{factorize, DecompMethod, LowRankConfig, RankStrategy};
use lowrank_gemm::trace::{matrix_with_spectrum, SpectrumKind};

const METHODS: [DecompMethod; 3] = [
    DecompMethod::ExactSvd,
    DecompMethod::RandomizedSvd,
    DecompMethod::Lanczos,
];

fn cost_scaling() {
    let cfg = config_from_env();
    let mut rng = Pcg64::seeded(11);
    let mut table = Table::new(
        "Decomposition cost scaling [ms] (rank = N/16)",
        &["N", "exact svd", "rsvd", "lanczos", "rsvd speedup"],
    );
    for n in [64usize, 128, 192, 256, 384] {
        let r = (n / 16).max(2);
        let a = Matrix::low_rank_noisy(n, n, r, 1e-3, &mut rng);
        let mut times = Vec::new();
        for method in METHODS {
            let lr_cfg = LowRankConfig {
                rank: RankStrategy::Fixed(r),
                method,
                ..Default::default()
            };
            let m = bench(&cfg, || {
                factorize(&a, &lr_cfg).unwrap();
            });
            times.push(m.mean_s);
        }
        table.row(&[
            n.to_string(),
            format!("{:8.2}", times[0] * 1e3),
            format!("{:8.2}", times[1] * 1e3),
            format!("{:8.2}", times[2] * 1e3),
            format!("{:5.1}x", times[0] / times[1]),
        ]);
    }
    table.print();
    println!("(paper §3.1: randomized methods dominate exact SVD as N grows.)\n");
}

fn quality_by_spectrum() {
    let mut rng = Pcg64::seeded(12);
    let n = 256;
    let r = 24;
    for kind in [
        SpectrumKind::ExponentialDecay,
        SpectrumKind::PowerLaw,
        SpectrumKind::Flat,
    ] {
        let a = matrix_with_spectrum(n, kind, &mut rng);
        let mut table = Table::new(
            &format!("Quality on {} spectrum (N={n}, r={r})", kind.name()),
            &["method", "rel err", "vs exact-svd"],
        );
        let mut exact_err = None;
        for method in METHODS {
            let lr_cfg = LowRankConfig {
                rank: RankStrategy::Fixed(r),
                method,
                storage: lowrank_gemm::fp8::StorageFormat::F32,
                ..Default::default()
            };
            let err = factorize(&a, &lr_cfg).unwrap().measured_error(&a);
            let base = *exact_err.get_or_insert(err);
            table.row(&[
                method.name().to_string(),
                format!("{err:.3e}"),
                format!("{:5.2}x", err / base),
            ]);
        }
        table.print();
        println!();
    }
}

fn oversampling_and_power_iters() {
    // rSVD tuning ablation: oversampling p and power iterations q trade
    // factorization time against tail-energy capture (Halko et al.).
    let cfg = config_from_env();
    let mut rng = Pcg64::seeded(13);
    let n = 256;
    let r = 16;
    let a = matrix_with_spectrum(n, SpectrumKind::PowerLaw, &mut rng);
    let mut table = Table::new(
        "rSVD tuning (N=256, r=16, power-law spectrum)",
        &["oversample", "power iters", "rel err", "ms"],
    );
    for &(p, q) in &[(0usize, 0usize), (8, 0), (8, 1), (8, 2), (16, 2), (32, 3)] {
        let lr_cfg = LowRankConfig {
            rank: RankStrategy::Fixed(r),
            method: DecompMethod::RandomizedSvd,
            storage: lowrank_gemm::fp8::StorageFormat::F32,
            rsvd: lowrank_gemm::linalg::RsvdOptions {
                oversample: p,
                power_iters: q,
                seed: 42,
            },
        };
        let err = factorize(&a, &lr_cfg).unwrap().measured_error(&a);
        let m = bench(&cfg, || {
            factorize(&a, &lr_cfg).unwrap();
        });
        table.row(&[
            p.to_string(),
            q.to_string(),
            format!("{err:.3e}"),
            format!("{:7.2}", m.mean_s * 1e3),
        ]);
    }
    table.print();
    println!("(q=2, p=8 is the shipped default — the knee of this curve.)");
}

fn main() {
    cost_scaling();
    quality_by_spectrum();
    oversampling_and_power_iters();
}
