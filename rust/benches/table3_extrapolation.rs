//! Table 3 reproduction: bandwidth-driven extrapolation to H200 / B200,
//! plus the §6.2 percent-of-peak arithmetic — with the audit notes the
//! paper needed (its "667 TFLOPS" bandwidth ceiling is a 1000x unit slip;
//! see DeviceProfile::bandwidth_limited_gemm_flops).

use lowrank_gemm::bench_harness::Table;
use lowrank_gemm::gpu_sim::{DeviceProfile, Precision, Roofline};

/// Paper Table 3, verbatim.
const PAPER: [(&str, f64, f64, f64, &str); 3] = [
    // (GPU, BW TB/s, fp8 peak PFLOPS, est TFLOPS, max N)
    ("RTX 4090", 1.0, 1.3, 378.0, "20,480"),
    ("H200", 4.8, 4.0, 1814.0, ">35,000"),
    ("B200", 8.0, 20.0, 3024.0, ">50,000"),
];

const MEASURED_4090_TFLOPS: f64 = 378.0; // the paper's anchor value

/// Largest square N whose three factorized matrices (plus dense-C
/// workspace at 1 B/elem) fit device memory at rank N/40 — the capacity
/// column of Table 3.
fn max_n_by_capacity(d: &DeviceProfile) -> usize {
    let mut n = 1024usize;
    loop {
        let r = (n / 40).max(16);
        let factored = 3 * (2 * n * r + r) as u64; // three matrices, fp8
        let workspace = (n * n) as u64; // one dense staging buffer
        if factored + workspace > d.memory_bytes {
            return n - 1024;
        }
        n += 1024;
    }
}

fn main() {
    let mut table = Table::new(
        "Table 3 — projected LowRank GEMM throughput (model | paper)",
        &["GPU", "BW", "FP8 peak", "Est. TFLOPS (model|paper)", "Max N (model|paper)"],
    );

    let anchor_bw = DeviceProfile::rtx4090().bandwidth_bps;
    for (name, bw_tb, fp8_pflops, paper_tflops, paper_maxn) in PAPER {
        let d = match name {
            "RTX 4090" => DeviceProfile::rtx4090(),
            "H200" => DeviceProfile::h200(),
            "B200" => DeviceProfile::b200(),
            _ => unreachable!(),
        };
        // The paper's extrapolation rule: scale the measured 378 TFLOPS by
        // the bandwidth ratio (§6.3).
        let projected = MEASURED_4090_TFLOPS * d.bandwidth_bps / anchor_bw;
        let max_n = max_n_by_capacity(&d);
        table.row(&[
            name.to_string(),
            format!("{bw_tb:.1} TB/s"),
            format!("{fp8_pflops:.1} PF"),
            format!("{projected:7.0} | {paper_tflops:7.0}"),
            format!("{max_n} | {paper_maxn}"),
        ]);
    }
    table.print();

    // §6.2 arithmetic, reproduced and audited.
    let d = DeviceProfile::rtx4090();
    println!("\n§6.2 percent-of-peak arithmetic (RTX 4090):");
    println!(
        "  step 1-3: 378 / 1321 TFLOPS = {:.1}% of FP8 compute peak (paper: 28.6%)",
        100.0 * MEASURED_4090_TFLOPS / (d.peak_fp8 / 1e12)
    );
    let stated = d.paper_stated_bw_ceiling_flops(Precision::Fp8) / 1e12;
    println!(
        "  step 4-5 (as stated): 378 / {stated:.0} TFLOPS = {:.1}% of 'bandwidth ceiling' (paper: 56.7%)",
        100.0 * MEASURED_4090_TFLOPS / stated
    );
    let literal = d.bandwidth_limited_gemm_flops(Precision::Fp8) / 1e12;
    println!(
        "  AUDIT: the paper's formula literally evaluates to {literal:.3} TFLOPS (= 667 GFLOPS),"
    );
    println!(
        "  a 1000x unit slip; the physical BW bound at N=20480 is {:.0} TFLOPS (2N/3 x BW),",
        d.physical_bw_limited_gemm_flops(20480, Precision::Fp8) / 1e12
    );
    println!("  i.e. ABOVE the compute peak: large dense GEMM on this card is compute-bound.");

    // Sanity: the roofline model's own large-N behaviour for the auto
    // pipeline, for comparison with the extrapolation rule.
    let rl = Roofline::new(DeviceProfile::h200());
    let sim = rl.lowrank_auto(35_000, 35_000 / 40);
    println!(
        "\n  model cross-check: simulated LowRankAuto on H200 @N=35000: {:.0} TFLOPS (paper's rule: 1814)",
        sim.tflops
    );
}
